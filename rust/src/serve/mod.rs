//! `segmul serve`: evaluation-as-a-service over HTTP.
//!
//! A dependency-free HTTP/1.1 front end (std::net only — the build is
//! fully offline) for the evaluation engine: clients POST a design +
//! workload to `/v1/eval` and get the same [`crate::error::ErrorMetrics`]
//! a local `segmul sweep` would compute — bit-identically, through the
//! same session layers (result cache, analytic registry, persistent
//! store). `/v1/tune` runs the [`crate::tune`] autotuner the same way:
//! an accuracy budget in, the winning configuration and Pareto frontier
//! out, with identical concurrent queries coalesced into one run.
//!
//! ## Architecture
//!
//! One **engine thread** owns the [`Session`] (and with it the
//! persistent worker pool); connection threads never touch it. Work
//! flows over a bounded queue:
//!
//! ```text
//! acceptor ─ thread-per-connection ─ admission ─▶ queue ─▶ engine ─▶ Session
//!                  │ 429 budget / 503 draining        │  coalesce
//!                  ◀──────── reply channel ◀──────────┘
//! ```
//!
//! The engine drains the whole queue each cycle and plans the batch
//! through [`coalesce::plan`]: concurrent requests for the same
//! [`crate::store::StoreKey`] share one pool evaluation. Sweep jobs
//! advance one grid point per cycle and re-enqueue themselves, so a
//! long sweep never starves interactive evals.
//!
//! ## Backpressure and shutdown
//!
//! Admission is a state machine with three states: **accepting** (queue
//! below `max_inflight`), **saturated** (typed 429 until the engine
//! drains), and **draining** (typed 503 for new work; in-flight work
//! completes, then the engine and acceptor exit). Draining is entered
//! by `POST /v1/shutdown`, [`Server::begin_drain`], or — in the CLI —
//! SIGINT/SIGTERM via [`install_drain_signals`]. Per-request deadlines
//! are enforced on the connection thread (`recv_timeout` on the reply
//! channel → typed 504) and propagated to the engine through a
//! cancellation flag so abandoned work is skipped, not evaluated.
//!
//! ## Supervision and graceful degradation
//!
//! The engine thread runs under a supervisor: a panic inside a cycle
//! (including one injected via the `engine.panic` fault site) is caught,
//! stranded requests get typed 500s through their dropped reply
//! channels, and the session is rebuilt — the server never dies from an
//! engine panic. Separately, a burst of pool-side failures (worker
//! panic storms, backend faults) flips the server into **degraded
//! mode**: requests whose design has an exact closed-form error model
//! are answered analytically with a `degraded: true` wire flag, other
//! requests get typed 503s, and the first non-analytic request of each
//! cycle probes the pool so the server returns to healthy on its own.

#![warn(clippy::unwrap_used, clippy::expect_used)]

pub mod client;
pub mod coalesce;
pub mod http;
pub mod metrics;
pub mod router;
pub mod wire;

use std::collections::VecDeque;
use std::net::{SocketAddr, TcpListener};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{Sender, SyncSender};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::api::{BackendChoice, Session, SessionTelemetry};
use crate::config::Config;
use crate::coordinator::{analytic_outcome, AnalyticMode, EvalJob, SweepOutcome};
use crate::error::SegmulError;
use crate::fault::{FaultInjector, FaultSite};

use self::http::Limits;
use self::metrics::ServerMetrics;

/// Server configuration. [`Default`] binds an ephemeral loopback port
/// with the CPU backend and the shared [`Config`] defaults for seed and
/// sample budget.
#[derive(Debug)]
pub struct ServeConfig {
    /// Bind address (`127.0.0.1:0` picks an ephemeral port).
    pub addr: String,
    /// Worker threads for the session pool (`None`: session default).
    pub workers: Option<usize>,
    /// Evaluation backend for the engine session.
    pub backend: BackendChoice,
    /// Answer-source policy for the engine session.
    pub analytic: AnalyticMode,
    /// Persistent result store directory, if any.
    pub store: Option<PathBuf>,
    /// Default RNG seed for requests that omit one.
    pub seed: u64,
    /// Default MC sample budget for `/v1/sweep` requests that omit one.
    pub mc_samples: u64,
    /// Exhaustive-vs-MC threshold for `/v1/sweep` grids.
    pub exhaustive_max_n: u32,
    /// Admission budget: queued work items beyond which new requests
    /// are rejected with a typed 429.
    pub max_inflight: usize,
    /// Deadline applied to requests that don't carry `deadline_ms`.
    pub default_deadline: Duration,
    /// Request-size limits.
    pub limits: Limits,
    /// Fault-injection plan shared with the session (tests and chaos
    /// runs; `None` falls back to `SEGMUL_FAULTS`). The supervisor
    /// re-threads the same plan into rebuilt sessions so one-shot
    /// triggers stay one-shot across restarts.
    pub faults: Option<Arc<FaultInjector>>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        let cfg = Config::default();
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            workers: None,
            backend: BackendChoice::Cpu,
            analytic: AnalyticMode::Off,
            store: None,
            seed: cfg.seed,
            mc_samples: cfg.mc_samples,
            exhaustive_max_n: cfg.exhaustive_max_n,
            max_inflight: 64,
            default_deadline: Duration::from_secs(30),
            limits: Limits::default(),
            faults: None,
        }
    }
}

/// Poison-safe lock: an engine panic is exactly what the supervisor
/// recovers from, and every guarded structure here (work queue,
/// telemetry snapshot, latency ring) stays internally consistent across
/// an unwind — so a poisoned mutex is business as usual, not a reason
/// to spread the panic to connection threads.
pub(crate) fn lock_clean<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// A reply to one eval request: the answered outcome plus whether it
/// was served in degraded (closed-form-only) mode.
pub(crate) type EvalReply = Result<(SweepOutcome, bool), SegmulError>;

/// One queued eval request.
pub(crate) struct EvalWork {
    pub job: EvalJob,
    pub reply: SyncSender<EvalReply>,
    pub cancelled: Arc<AtomicBool>,
}

/// One queued (possibly partially completed) sweep: the engine runs one
/// grid point per cycle and re-enqueues the remainder.
pub(crate) struct SweepWork {
    pub jobs: VecDeque<EvalJob>,
    pub events: Sender<SweepEvent>,
    pub cancelled: Arc<AtomicBool>,
}

/// A reply to one tune request: the autotuner's full result plus the
/// degraded flag (always `false` today — tune work is rejected, not
/// degraded, while the pool is unhealthy — kept for wire symmetry with
/// eval answers).
pub(crate) type TuneReply = Result<(Box<crate::tune::TuneResult>, bool), SegmulError>;

/// One queued tune request. Identical concurrent queries (by
/// [`crate::tune::TuneQuery::canonical`]) coalesce into one autotuner
/// run whose result every waiter shares.
pub(crate) struct TuneWork {
    pub query: crate::tune::TuneQuery,
    pub reply: SyncSender<TuneReply>,
    pub cancelled: Arc<AtomicBool>,
}

pub(crate) enum Work {
    Eval(EvalWork),
    Sweep(SweepWork),
    Tune(TuneWork),
}

/// Engine → connection-thread stream events for `/v1/sweep`. The `Row`
/// flag marks degraded (closed-form-only) answers.
pub(crate) enum SweepEvent {
    Row(Box<SweepOutcome>, bool),
    Failed(SegmulError),
    Done,
}

/// State shared between the acceptor, connection threads, and the
/// engine.
pub(crate) struct Shared {
    pub cfg: ServeConfig,
    pub metrics: ServerMetrics,
    pub queue: Mutex<VecDeque<Work>>,
    pub ready: Condvar,
    pub draining: AtomicBool,
    /// Degraded mode: the pool is unhealthy (failure burst or a panic
    /// the supervisor is recovering from); only closed-form-eligible
    /// requests are answered until a probe succeeds.
    pub degraded: AtomicBool,
    pub engine_done: AtomicBool,
    pub conn_active: AtomicUsize,
    /// Backend identity, published by the engine at startup — served in
    /// `/metrics`, `/healthz`, and every eval response so clients can
    /// assert which backend actually answered.
    pub backend: OnceLock<&'static str>,
    pub batch: OnceLock<usize>,
    /// Telemetry snapshot, refreshed by the engine after every cycle.
    pub telemetry: Mutex<SessionTelemetry>,
}

impl Shared {
    fn new(cfg: ServeConfig) -> Self {
        Shared {
            cfg,
            metrics: ServerMetrics::default(),
            queue: Mutex::new(VecDeque::new()),
            ready: Condvar::new(),
            draining: AtomicBool::new(false),
            degraded: AtomicBool::new(false),
            engine_done: AtomicBool::new(false),
            conn_active: AtomicUsize::new(0),
            backend: OnceLock::new(),
            batch: OnceLock::new(),
            telemetry: Mutex::new(SessionTelemetry::default()),
        }
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.get().copied().unwrap_or("starting")
    }

    /// Admission control: reject with a typed 503 while draining, a
    /// typed 429 when the in-flight budget is exhausted; otherwise
    /// enqueue and wake the engine.
    pub fn admit(&self, work: Work) -> Result<(), SegmulError> {
        if self.draining.load(Ordering::SeqCst) {
            return Err(SegmulError::serve(
                503,
                "server is draining; in-flight work completes but no new work is admitted",
            ));
        }
        let mut q = lock_clean(&self.queue);
        if q.len() >= self.cfg.max_inflight {
            return Err(SegmulError::serve(
                429,
                format!("in-flight budget of {} work items is exhausted; retry later", q.len()),
            ));
        }
        self.metrics.record_queue_depth(q.len());
        q.push_back(work);
        self.ready.notify_one();
        Ok(())
    }

    pub fn queue_depth(&self) -> usize {
        lock_clean(&self.queue).len()
    }
}

/// Drain summary returned by [`Server::join`].
#[derive(Clone, Debug)]
pub struct ServeSummary {
    /// Final session telemetry.
    pub telemetry: SessionTelemetry,
    /// Total requests accepted over the server's life.
    pub requests_total: u64,
    /// Backend that served the run.
    pub backend: String,
    /// The final `/metrics` document.
    pub metrics_doc: String,
}

/// A running server: an acceptor thread, an engine thread, and the
/// shared state between them. Dropping the handle does **not** stop the
/// server — call [`Server::begin_drain`] (or hit `POST /v1/shutdown`)
/// and then [`Server::join`].
pub struct Server {
    shared: Arc<Shared>,
    addr: SocketAddr,
    acceptor: JoinHandle<()>,
    engine: JoinHandle<()>,
}

impl Server {
    /// Bind, build the session (backend factories run now — a missing
    /// artifact directory fails here, not on the first request), and
    /// spawn the engine + acceptor threads.
    pub fn start(cfg: ServeConfig) -> Result<Server, SegmulError> {
        let listener = TcpListener::bind(&cfg.addr)
            .map_err(|e| SegmulError::serve(500, format!("cannot bind {}: {e}", cfg.addr)))?;
        let addr = listener
            .local_addr()
            .map_err(|e| SegmulError::serve(500, format!("cannot resolve bound address: {e}")))?;
        let session = build_session(&cfg)?;
        let shared = Arc::new(Shared::new(cfg));
        // Publish identity before any thread runs, so the CLI can print
        // the backend deterministically right after start().
        let _ = shared.backend.set(session.backend_name());
        let _ = shared.batch.set(session.batch());
        *lock_clean(&shared.telemetry) = session.telemetry();
        let engine = {
            let shared = shared.clone();
            std::thread::Builder::new()
                .name("segmul-serve-engine".into())
                .spawn(move || engine_loop(&shared, session))
                .map_err(|e| SegmulError::serve(500, format!("cannot spawn engine: {e}")))?
        };
        let acceptor = {
            let shared = shared.clone();
            std::thread::Builder::new()
                .name("segmul-serve-acceptor".into())
                .spawn(move || acceptor_loop(&shared, listener))
                .map_err(|e| SegmulError::serve(500, format!("cannot spawn acceptor: {e}")))?
        };
        Ok(Server { shared, addr, acceptor, engine })
    }

    /// The bound address (resolves `:0` ephemeral ports).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Identity of the backend the engine's session holds.
    pub fn backend_name(&self) -> &'static str {
        self.shared.backend_name()
    }

    /// Enter the draining state: new work is rejected with 503,
    /// in-flight work completes, then the threads exit.
    pub fn begin_drain(&self) {
        self.shared.draining.store(true, Ordering::SeqCst);
        self.shared.ready.notify_all();
    }

    /// Whether a drain has been requested (by handle, endpoint, or
    /// signal).
    pub fn draining(&self) -> bool {
        self.shared.draining.load(Ordering::SeqCst)
    }

    /// Wait for the drain to complete and return the final summary.
    /// Blocks until a drain is requested; in-flight work finishes,
    /// lingering connection threads get a bounded grace period.
    pub fn join(self) -> ServeSummary {
        let _ = self.engine.join();
        let _ = self.acceptor.join();
        let grace = Instant::now();
        while self.shared.conn_active.load(Ordering::SeqCst) > 0
            && grace.elapsed() < Duration::from_secs(5)
        {
            std::thread::sleep(Duration::from_millis(10));
        }
        let telemetry = lock_clean(&self.shared.telemetry).clone();
        let backend = self.shared.backend_name().to_string();
        let degraded = self.shared.degraded.load(Ordering::SeqCst);
        let metrics_doc = self.shared.metrics.render(&telemetry, &backend, true, degraded, 0);
        ServeSummary {
            requests_total: self.shared.metrics.requests_total.load(Ordering::Relaxed),
            telemetry,
            backend,
            metrics_doc,
        }
    }
}

/// Build the engine's [`Session`] from the server configuration. Called
/// at startup and again by the supervisor after an engine panic.
fn build_session(cfg: &ServeConfig) -> Result<Session, SegmulError> {
    let mut builder = Session::builder()
        .backend(cfg.backend.clone())
        .seed(cfg.seed)
        .analytic(cfg.analytic);
    if let Some(w) = cfg.workers {
        builder = builder.workers(w);
    }
    if let Some(dir) = &cfg.store {
        builder = builder.store(dir.clone());
    }
    if let Some(f) = &cfg.faults {
        builder = builder.faults(f.clone());
    }
    builder.build()
}

/// The engine supervisor: runs [`engine_cycles`] under `catch_unwind`
/// and rebuilds the session after a panic instead of letting the server
/// die. A panic drops the in-flight batch — every stranded reply sender
/// closes, which the connection threads surface as typed 500s — and
/// flips the server into degraded mode until the rebuilt pool answers a
/// probe. While a rebuild itself fails, queued work is answered in
/// closed form where possible so the service keeps limping, not hanging.
fn engine_loop(shared: &Arc<Shared>, session: Session) {
    let mut live = Some(session);
    loop {
        match live.take() {
            Some(session) => {
                if catch_unwind(AssertUnwindSafe(|| engine_cycles(shared, session))).is_ok() {
                    return; // clean drain exit; engine_done is set
                }
                shared.metrics.engine_restarts.fetch_add(1, Ordering::Relaxed);
                shared.degraded.store(true, Ordering::SeqCst);
                eprintln!("warning: serve engine panicked; rebuilding the session");
            }
            None => match build_session(&shared.cfg) {
                Ok(session) => live = Some(session),
                Err(e) => {
                    eprintln!("warning: serve engine rebuild failed ({e}); retrying");
                    degraded_cycle(shared);
                    if shared.draining.load(Ordering::SeqCst) && shared.queue_depth() == 0 {
                        shared.engine_done.store(true, Ordering::SeqCst);
                        return;
                    }
                    std::thread::sleep(Duration::from_millis(50));
                }
            },
        }
    }
}

/// Pool-health tracking for degraded-mode transitions: a short burst of
/// consecutive pool-side failures (worker panics that exhausted their
/// retries, backend faults) degrades the server; any successful pool
/// answer restores it.
struct EngineHealth {
    pool_failures: u32,
}

impl EngineHealth {
    /// Consecutive pool-side failures before the server degrades.
    const DEGRADE_AFTER: u32 = 2;

    fn new() -> EngineHealth {
        EngineHealth { pool_failures: 0 }
    }

    fn record_ok(&mut self, shared: &Shared) {
        self.pool_failures = 0;
        if shared.degraded.swap(false, Ordering::SeqCst) {
            eprintln!("serve: pool answered a probe; leaving degraded mode");
        }
    }

    fn record_failure(&mut self, shared: &Shared, e: &SegmulError) {
        if !matches!(e.kind(), "eval" | "backend") {
            return; // client-caused errors say nothing about pool health
        }
        self.pool_failures += 1;
        if self.pool_failures >= Self::DEGRADE_AFTER
            && !shared.degraded.swap(true, Ordering::SeqCst)
        {
            eprintln!(
                "warning: serve degraded after {} consecutive pool failures ({e}); \
                 answering closed-form-eligible requests only",
                self.pool_failures
            );
        }
    }
}

/// The typed rejection for non-analytic work while degraded.
fn degraded_error() -> SegmulError {
    SegmulError::serve(
        503,
        "evaluation pool is degraded; only designs with exact closed-form error models \
         are answered until the pool recovers",
    )
}

/// The engine: the only thread that touches the [`Session`]. Drains the
/// queue in batches, coalesces eval requests, advances sweeps one grid
/// point at a time, and exits once draining is requested and the queue
/// is empty. Panics propagate to the supervisor in [`engine_loop`].
fn engine_cycles(shared: &Arc<Shared>, mut session: Session) {
    let _ = shared.backend.set(session.backend_name());
    let _ = shared.batch.set(session.batch());
    *lock_clean(&shared.telemetry) = session.telemetry();
    let mut health = EngineHealth::new();
    loop {
        let batch: Vec<Work> = {
            let mut q = lock_clean(&shared.queue);
            loop {
                if !q.is_empty() {
                    break q.drain(..).collect();
                }
                if shared.draining.load(Ordering::SeqCst) {
                    shared.engine_done.store(true, Ordering::SeqCst);
                    return;
                }
                q = match shared.ready.wait_timeout(q, Duration::from_millis(50)) {
                    Ok((guard, _)) => guard,
                    Err(poisoned) => poisoned.into_inner().0,
                };
            }
        };
        // The engine-panic seam fires with the batch drained and no lock
        // held: the dropped reply senders become typed 500s and the
        // supervisor restarts the session.
        if session.faults().fire(FaultSite::EnginePanic) {
            panic!("injected engine panic");
        }
        let mut evals: Vec<EvalWork> = Vec::new();
        let mut sweeps: Vec<SweepWork> = Vec::new();
        let mut tunes: Vec<TuneWork> = Vec::new();
        for work in batch {
            match work {
                Work::Eval(e) => {
                    if !e.cancelled.load(Ordering::SeqCst) {
                        evals.push(e);
                    }
                }
                Work::Sweep(s) => {
                    if !s.cancelled.load(Ordering::SeqCst) {
                        sweeps.push(s);
                    }
                }
                Work::Tune(t) => {
                    if !t.cancelled.load(Ordering::SeqCst) {
                        tunes.push(t);
                    }
                }
            }
        }
        run_evals(shared, &mut session, &evals, &mut health);
        run_tunes(shared, &mut session, tunes, &mut health);
        run_sweeps(shared, &mut session, sweeps, &mut health);
        *lock_clean(&shared.telemetry) = session.telemetry();
    }
}

/// Answer one job with the pool healthy-path, updating health tracking.
fn pool_answer(
    shared: &Shared,
    session: &mut Session,
    health: &mut EngineHealth,
    job: &EvalJob,
) -> EvalReply {
    match session.run_outcome(job) {
        Ok(o) => {
            health.record_ok(shared);
            Ok((o, false))
        }
        Err(e) => {
            health.record_failure(shared, &e);
            Err(e)
        }
    }
}

/// Answer one job while degraded: closed-form if eligible; otherwise the
/// caller decides between probing the pool and a typed 503.
fn closed_form_answer(shared: &Shared, job: &EvalJob) -> Option<EvalReply> {
    let o = analytic_outcome(job)?;
    shared.metrics.degraded_answers.fetch_add(1, Ordering::Relaxed);
    Some(Ok((o, true)))
}

/// Plan and dispatch one drained batch of eval requests: exact-key
/// duplicates share a single evaluation, groups of one coalesce class
/// run consecutively. While degraded, analytic-eligible groups are
/// answered in closed form and the first non-analytic group probes the
/// pool (recovering the server if it succeeds); the rest get typed 503s.
fn run_evals(shared: &Arc<Shared>, session: &mut Session, evals: &[EvalWork], health: &mut EngineHealth) {
    if evals.is_empty() {
        return;
    }
    let backend = session.backend_name();
    let batch_size = session.batch();
    let jobs: Vec<EvalJob> = evals.iter().map(|e| e.job.clone()).collect();
    let plan = coalesce::plan(&jobs, backend, batch_size);
    shared.metrics.coalesce_requests.fetch_add(evals.len() as u64, Ordering::Relaxed);
    let mut probed = false;
    for group in plan.groups {
        // Skip work every waiter has abandoned (deadline expiry).
        if group.requests.iter().all(|&i| evals[i].cancelled.load(Ordering::SeqCst)) {
            continue;
        }
        let result: EvalReply = if shared.degraded.load(Ordering::SeqCst) {
            match closed_form_answer(shared, &group.job) {
                Some(r) => r,
                None if !probed => {
                    probed = true;
                    pool_answer(shared, session, health, &group.job)
                }
                None => Err(degraded_error()),
            }
        } else {
            pool_answer(shared, session, health, &group.job)
        };
        if let Ok((o, _)) = &result {
            // A pool dispatch happened only for fresh simulated answers;
            // cache/store/analytic answers amortize like merged requests.
            if o.source() == "simulated" && !o.cached {
                shared.metrics.coalesce_dispatched.fetch_add(1, Ordering::Relaxed);
            }
        }
        for &i in &group.requests {
            let _ = evals[i].reply.send(result.clone());
        }
    }
}

/// Answer the drained tune requests, coalescing identical queries (by
/// canonical identity) into one autotuner run. The tuner itself goes
/// through the session's answer-source ladder, so its grid points hit
/// the same cache/store/analytic layers an eval would. While degraded,
/// tune work is rejected with a typed 503 — a tuning decision spanning
/// a whole grid should not be made from a limping pool.
fn run_tunes(
    shared: &Arc<Shared>,
    session: &mut Session,
    tunes: Vec<TuneWork>,
    health: &mut EngineHealth,
) {
    if tunes.is_empty() {
        return;
    }
    let mut groups: Vec<(String, Vec<TuneWork>)> = Vec::new();
    for work in tunes {
        let key = work.query.canonical();
        match groups.iter_mut().find(|(k, _)| *k == key) {
            Some((_, members)) => members.push(work),
            None => groups.push((key, vec![work])),
        }
    }
    for (_, members) in groups {
        if members.iter().all(|w| w.cancelled.load(Ordering::SeqCst)) {
            continue;
        }
        shared.metrics.coalesce_requests.fetch_add(members.len() as u64, Ordering::Relaxed);
        let result: TuneReply = if shared.degraded.load(Ordering::SeqCst) {
            Err(degraded_error())
        } else {
            match crate::tune::tune(session, &members[0].query) {
                Ok(r) => {
                    health.record_ok(shared);
                    Ok((Box::new(r), false))
                }
                Err(e) => {
                    health.record_failure(shared, &e);
                    Err(e)
                }
            }
        };
        for w in &members {
            let _ = w.reply.send(result.clone());
        }
    }
}

/// Advance each live sweep by one grid point; unfinished sweeps go back
/// to the queue so interactive evals interleave with long grids. While
/// degraded, grid points are answered in closed form where eligible and
/// the sweep fails typed on the first point that needs the pool.
fn run_sweeps(
    shared: &Arc<Shared>,
    session: &mut Session,
    sweeps: Vec<SweepWork>,
    health: &mut EngineHealth,
) {
    for mut sweep in sweeps {
        let Some(job) = sweep.jobs.pop_front() else {
            let _ = sweep.events.send(SweepEvent::Done);
            continue;
        };
        let result: EvalReply = if shared.degraded.load(Ordering::SeqCst) {
            closed_form_answer(shared, &job).unwrap_or_else(|| Err(degraded_error()))
        } else {
            pool_answer(shared, session, health, &job)
        };
        match result {
            Ok((outcome, degraded)) => {
                if sweep.events.send(SweepEvent::Row(Box::new(outcome), degraded)).is_err() {
                    continue; // client gone: drop the sweep
                }
                if sweep.jobs.is_empty() {
                    let _ = sweep.events.send(SweepEvent::Done);
                } else {
                    // Re-enqueue directly: the sweep was already admitted
                    // once and must be able to finish during a drain.
                    let mut q = lock_clean(&shared.queue);
                    q.push_back(Work::Sweep(sweep));
                }
            }
            Err(e) => {
                let _ = sweep.events.send(SweepEvent::Failed(e));
            }
        }
    }
}

/// One queue drain with no session at all (the supervisor could not
/// rebuild yet): closed-form-eligible work is still answered — flagged
/// degraded — and everything else fails typed instead of hanging until
/// its deadline.
fn degraded_cycle(shared: &Arc<Shared>) {
    let batch: Vec<Work> = {
        let mut q = lock_clean(&shared.queue);
        q.drain(..).collect()
    };
    for work in batch {
        match work {
            Work::Eval(e) => {
                if e.cancelled.load(Ordering::SeqCst) {
                    continue;
                }
                let reply =
                    closed_form_answer(shared, &e.job).unwrap_or_else(|| Err(degraded_error()));
                let _ = e.reply.send(reply);
            }
            Work::Tune(t) => {
                if t.cancelled.load(Ordering::SeqCst) {
                    continue;
                }
                // A grid-wide tuning decision needs a healthy pool.
                let _ = t.reply.send(Err(degraded_error()));
            }
            Work::Sweep(mut s) => {
                if s.cancelled.load(Ordering::SeqCst) {
                    continue;
                }
                // Answer the whole remaining grid now: analytic points
                // stream out flagged degraded, the first pool-needing
                // point fails the sweep typed.
                loop {
                    let Some(job) = s.jobs.pop_front() else {
                        let _ = s.events.send(SweepEvent::Done);
                        break;
                    };
                    match closed_form_answer(shared, &job) {
                        Some(Ok((o, d))) => {
                            if s.events.send(SweepEvent::Row(Box::new(o), d)).is_err() {
                                break; // client gone
                            }
                        }
                        _ => {
                            let _ = s.events.send(SweepEvent::Failed(degraded_error()));
                            break;
                        }
                    }
                }
            }
        }
    }
}

/// The acceptor: non-blocking accept loop, one detached thread per
/// connection. Keeps answering during a drain (so late clients get
/// typed 503s and `/metrics` stays scrapeable) and exits once the
/// engine has finished.
fn acceptor_loop(shared: &Arc<Shared>, listener: TcpListener) {
    if listener.set_nonblocking(true).is_err() {
        return;
    }
    loop {
        if drain_requested() {
            shared.draining.store(true, Ordering::SeqCst);
        }
        if shared.engine_done.load(Ordering::SeqCst) {
            return;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                shared.conn_active.fetch_add(1, Ordering::SeqCst);
                let shared = shared.clone();
                let _ = std::thread::Builder::new()
                    .name("segmul-serve-conn".into())
                    .spawn(move || {
                        router::handle(&shared, stream);
                        shared.conn_active.fetch_sub(1, Ordering::SeqCst);
                    });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(25));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(25)),
        }
    }
}

/// Process-wide drain request, set by the signal handler (the acceptor
/// polls it and folds it into the server's draining state).
static GLOBAL_DRAIN: AtomicBool = AtomicBool::new(false);

/// Whether SIGINT/SIGTERM requested a drain.
pub fn drain_requested() -> bool {
    GLOBAL_DRAIN.load(Ordering::SeqCst)
}

/// Install SIGINT/SIGTERM handlers that request a graceful drain. The
/// handler is async-signal-safe: it only stores to an atomic. Unix
/// only; a no-op elsewhere. Installed by the CLI, never by tests (which
/// drain via `POST /v1/shutdown`).
#[cfg(unix)]
pub fn install_drain_signals() {
    extern "C" fn on_signal(_sig: i32) {
        GLOBAL_DRAIN.store(true, Ordering::SeqCst);
    }
    // std links libc on unix; declaring `signal` directly avoids a
    // dependency on a signal crate (the build is offline).
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    unsafe {
        signal(2, on_signal as usize); // SIGINT
        signal(15, on_signal as usize); // SIGTERM
    }
}

#[cfg(not(unix))]
/// No-op on non-Unix targets.
pub fn install_drain_signals() {}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;
    use crate::util::json::Json;

    /// End-to-end loopback smoke: boot, health, one eval, drain, join.
    #[test]
    fn boots_serves_and_drains() {
        let cfg = ServeConfig {
            workers: Some(2),
            max_inflight: 8,
            default_deadline: Duration::from_secs(30),
            ..ServeConfig::default()
        };
        let server = Server::start(cfg).unwrap();
        let addr = server.addr();

        let health = client::get(addr, "/healthz").unwrap();
        assert_eq!(health.status, 200);
        let body = health.json().unwrap();
        assert_eq!(body.get("status").and_then(Json::as_str), Some("ok"));

        let eval = client::post_json(
            addr,
            "/v1/eval",
            &Json::parse(
                r#"{"design":{"family":"segmented","n":8,"t":3,"fix":true},
                    "workload":{"kind":"mc","samples":50000,"seed":7}}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(eval.status, 200, "{}", eval.text());
        let row = eval.json().unwrap();
        assert_eq!(row.get("source").and_then(Json::as_str), Some("simulated"));
        assert_eq!(row.get("backend").and_then(Json::as_str), Some("cpu"));
        assert!(row.get("metrics").unwrap().get("mae").unwrap().as_f64().unwrap() > 0.0);

        let down = client::post_json(addr, "/v1/shutdown", &Json::Obj(Default::default())).unwrap();
        assert_eq!(down.status, 200);
        let summary = server.join();
        assert_eq!(summary.backend, "cpu");
        assert!(summary.requests_total >= 3);
        assert_eq!(summary.telemetry.jobs_completed, 1);
    }
}
