//! Minimal blocking loopback HTTP client.
//!
//! Shared by the integration tests, the latency bench, and the
//! `serve_eval` example so none of them hand-roll socket code. One
//! request per connection (matching the server's `Connection: close`
//! policy); chunked response bodies are decoded transparently.

use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpStream};
use std::time::Duration;

use crate::error::SegmulError;
use crate::util::json::Json;

/// A fully read response.
#[derive(Clone, Debug)]
pub struct Response {
    /// HTTP status code.
    pub status: u16,
    /// Header name (lowercased) / value pairs.
    pub headers: Vec<(String, String)>,
    /// Body bytes, de-chunked if the response was chunk-encoded.
    pub body: Vec<u8>,
}

impl Response {
    /// First header value for `name` (case-insensitive).
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(k, _)| *k == name).map(|(_, v)| v.as_str())
    }

    /// Body as UTF-8 text (lossy).
    pub fn text(&self) -> String {
        String::from_utf8_lossy(&self.body).into_owned()
    }

    /// Body parsed as JSON (typed error otherwise).
    pub fn json(&self) -> Result<Json, SegmulError> {
        Json::parse(&self.text())
            .map_err(|e| SegmulError::Io(format!("response body is not JSON: {e}")))
    }

    /// Non-empty body lines, each parsed as JSON (ndjson streams).
    pub fn json_lines(&self) -> Result<Vec<Json>, SegmulError> {
        self.text()
            .lines()
            .filter(|l| !l.trim().is_empty())
            .map(|l| {
                Json::parse(l).map_err(|e| SegmulError::Io(format!("bad ndjson line {l:?}: {e}")))
            })
            .collect()
    }
}

fn io(e: std::io::Error, what: &str) -> SegmulError {
    SegmulError::Io(format!("{what}: {e}"))
}

/// `GET path`.
pub fn get(addr: SocketAddr, path: &str) -> Result<Response, SegmulError> {
    request(addr, "GET", path, None)
}

/// `POST path` with a JSON body.
pub fn post_json(addr: SocketAddr, path: &str, body: &Json) -> Result<Response, SegmulError> {
    request(addr, "POST", path, Some(body.to_string_compact().into_bytes()))
}

/// `POST path` with verbatim body bytes (malformed-payload tests).
pub fn post_bytes(addr: SocketAddr, path: &str, body: &[u8]) -> Result<Response, SegmulError> {
    request(addr, "POST", path, Some(body.to_vec()))
}

/// A well-formed one-shot request.
pub fn request(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: Option<Vec<u8>>,
) -> Result<Response, SegmulError> {
    let body = body.unwrap_or_default();
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: segmul\r\nContent-Length: {}\r\n\r\n",
        body.len()
    );
    let mut raw = head.into_bytes();
    raw.extend_from_slice(&body);
    send_bytes(addr, &raw)
}

/// Write raw bytes — malformed on purpose or otherwise — and read back
/// whatever the server answers. The write side is half-closed after the
/// payload so the server sees EOF instead of a stalled read.
pub fn send_bytes(addr: SocketAddr, raw: &[u8]) -> Result<Response, SegmulError> {
    let mut stream =
        TcpStream::connect(addr).map_err(|e| io(e, &format!("connect {addr}")))?;
    stream
        .set_read_timeout(Some(Duration::from_secs(120)))
        .map_err(|e| io(e, "set_read_timeout"))?;
    let _ = stream.set_nodelay(true);
    stream.write_all(raw).map_err(|e| io(e, "write request"))?;
    stream.flush().map_err(|e| io(e, "flush request"))?;
    let _ = stream.shutdown(Shutdown::Write);
    let mut buf = Vec::new();
    stream.read_to_end(&mut buf).map_err(|e| io(e, "read response"))?;
    parse_response(&buf)
}

/// Parse a complete response byte buffer (head + body, chunked or not).
pub fn parse_response(buf: &[u8]) -> Result<Response, SegmulError> {
    let head_end = buf
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .ok_or_else(|| SegmulError::Io("response head never terminated".into()))?;
    let head = std::str::from_utf8(&buf[..head_end])
        .map_err(|_| SegmulError::Io("response head is not UTF-8".into()))?;
    let mut lines = head.split("\r\n");
    let status_line = lines.next().unwrap_or_default();
    let status = status_line
        .split(' ')
        .nth(1)
        .and_then(|s| s.parse::<u16>().ok())
        .ok_or_else(|| SegmulError::Io(format!("bad status line {status_line:?}")))?;
    let mut headers = Vec::new();
    for line in lines {
        let Some((name, value)) = line.split_once(':') else {
            return Err(SegmulError::Io(format!("bad response header {line:?}")));
        };
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }
    let mut resp = Response { status, headers, body: buf[head_end + 4..].to_vec() };
    let chunked = resp
        .header("transfer-encoding")
        .is_some_and(|v| v.eq_ignore_ascii_case("chunked"));
    if chunked {
        resp.body = dechunk(&resp.body)?;
    }
    Ok(resp)
}

/// Decode a chunked transfer-encoding body.
fn dechunk(mut rest: &[u8]) -> Result<Vec<u8>, SegmulError> {
    let mut out = Vec::with_capacity(rest.len());
    loop {
        let line_end = rest
            .windows(2)
            .position(|w| w == b"\r\n")
            .ok_or_else(|| SegmulError::Io("chunk size line never terminated".into()))?;
        let size_text = std::str::from_utf8(&rest[..line_end])
            .map_err(|_| SegmulError::Io("chunk size line is not UTF-8".into()))?;
        let size = usize::from_str_radix(size_text.trim(), 16)
            .map_err(|_| SegmulError::Io(format!("bad chunk size {size_text:?}")))?;
        rest = &rest[line_end + 2..];
        if size == 0 {
            return Ok(out);
        }
        if rest.len() < size + 2 {
            return Err(SegmulError::Io(format!(
                "truncated chunk: want {size} bytes + CRLF, have {}",
                rest.len()
            )));
        }
        out.extend_from_slice(&rest[..size]);
        rest = &rest[size + 2..];
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;

    #[test]
    fn parses_a_fixed_length_response() {
        let raw = b"HTTP/1.1 404 Not Found\r\nContent-Type: application/json\r\nContent-Length: 2\r\n\r\n{}";
        let r = parse_response(raw).unwrap();
        assert_eq!(r.status, 404);
        assert_eq!(r.header("content-type"), Some("application/json"));
        assert_eq!(r.body, b"{}");
    }

    #[test]
    fn dechunks_a_streamed_body() {
        let raw = b"HTTP/1.1 200 OK\r\nTransfer-Encoding: chunked\r\n\r\n4\r\nab\r\n\r\n6\r\ncd\r\nef\r\n0\r\n\r\n";
        let r = parse_response(raw).unwrap();
        assert_eq!(r.body, b"ab\r\ncd\r\nef");
        // ndjson framing: each json_line chunk is one line.
        let raw = b"HTTP/1.1 200 OK\r\nTransfer-Encoding: chunked\r\n\r\n8\r\n{\"a\":1}\n\r\n8\r\n{\"b\":2}\n\r\n0\r\n\r\n";
        let lines = parse_response(raw).unwrap().json_lines().unwrap();
        assert_eq!(lines.len(), 2);
        assert_eq!(lines[0].get("a").unwrap().as_u64(), Some(1));
    }

    #[test]
    fn truncated_chunk_streams_are_typed_errors() {
        let raw = b"HTTP/1.1 200 OK\r\nTransfer-Encoding: chunked\r\n\r\nff\r\nab";
        assert!(parse_response(raw).is_err());
        let raw = b"HTTP/1.1 200 OK\r\nTransfer-Encoding: chunked\r\n\r\nzz\r\n";
        assert!(parse_response(raw).is_err());
    }
}
