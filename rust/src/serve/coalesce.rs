//! Request coalescing: merge compatible concurrent eval requests into
//! shared pool jobs.
//!
//! The engine drains its bounded queue in batches; before dispatching, a
//! batch of eval requests is grouped by **coalesce class** — the
//! persistent [`StoreKey`] identity *modulo seed and sample budget*
//! (canonical design, workload kind, backend name, batch size). Within a
//! class, requests with the *exact* same [`StoreKey`] are provably the
//! same evaluation (the pool's ordered merge is deterministic), so one
//! pool job answers all of them; distinct keys of one class run
//! back-to-back against the same warm kernels. Concurrent clients
//! asking the service the same question therefore cost one backend
//! dispatch, not N.

use std::collections::BTreeMap;

use crate::coordinator::EvalJob;
use crate::store::StoreKey;
use crate::util::json::{obj, Json};

/// The coalesce-class key: the [`StoreKey`] identity with the seed and
/// sample budget erased. Two jobs in one class evaluate the same
/// canonical design under the same workload *kind* on the same backend
/// and chunk layout — the compatibility condition for sharing a drain
/// batch's warm dispatch.
pub fn class_key(job: &EvalJob, backend: &str, batch: usize) -> String {
    let key = job.key();
    let kind = match key.spec {
        crate::coordinator::SpecKey::Exhaustive => "exhaustive",
        crate::coordinator::SpecKey::MonteCarlo { .. } => "mc",
        crate::coordinator::SpecKey::Adaptive { .. } => "adaptive",
    };
    obj(vec![
        ("backend", Json::from(backend)),
        ("batch", Json::from(batch as u64)),
        ("design", key.design.to_json()),
        ("workload_kind", Json::from(kind)),
    ])
    .to_string_compact()
}

/// One dispatch group: a single job to evaluate plus the indexes (into
/// the drained batch) of every request it answers.
#[derive(Clone, Debug)]
pub struct Group {
    /// The job to dispatch once.
    pub job: EvalJob,
    /// Indexes (into the drained batch) of the requests it answers.
    pub requests: Vec<usize>,
}

/// The dispatch plan for one drained batch of eval requests.
#[derive(Clone, Debug, Default)]
pub struct CoalescePlan {
    /// Unique evaluations, in first-arrival order of (class, key).
    pub groups: Vec<Group>,
    /// Requests answered by another request's evaluation in this batch.
    pub merged: u64,
}

/// Plan a drained batch: group by coalesce class, dedupe exact
/// [`StoreKey`] duplicates within each class, and order groups so one
/// class's jobs dispatch consecutively (warm-kernel locality). Ordering
/// is deterministic: classes by first arrival, jobs within a class by
/// first arrival.
pub fn plan(jobs: &[EvalJob], backend: &str, batch: usize) -> CoalescePlan {
    let mut class_order: Vec<String> = Vec::new();
    // class -> (exact key -> group index in `groups`)
    let mut classes: BTreeMap<String, BTreeMap<String, usize>> = BTreeMap::new();
    // class -> groups in first-arrival order
    let mut per_class: BTreeMap<String, Vec<Group>> = BTreeMap::new();
    let mut merged = 0u64;
    for (idx, job) in jobs.iter().enumerate() {
        let class = class_key(job, backend, batch);
        let exact = StoreKey::new(job, backend, batch).canonical().to_string();
        if !classes.contains_key(&class) {
            class_order.push(class.clone());
        }
        let keys = classes.entry(class.clone()).or_default();
        let groups = per_class.entry(class).or_default();
        match keys.get(&exact) {
            Some(&g) => {
                groups[g].requests.push(idx);
                merged += 1;
            }
            None => {
                keys.insert(exact, groups.len());
                groups.push(Group { job: job.clone(), requests: vec![idx] });
            }
        }
    }
    let mut groups = Vec::with_capacity(jobs.len());
    for class in class_order {
        groups.extend(per_class.remove(&class).unwrap_or_default());
    }
    CoalescePlan { groups, merged }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mc(n: u32, t: u32, samples: u64, seed: u64) -> EvalJob {
        EvalJob::mc(n, t, false, samples, seed)
    }

    #[test]
    fn exact_duplicates_share_one_group() {
        let jobs = vec![mc(8, 3, 100, 1), mc(8, 3, 100, 1), mc(8, 3, 100, 1)];
        let plan = plan(&jobs, "cpu", 4096);
        assert_eq!(plan.groups.len(), 1);
        assert_eq!(plan.groups[0].requests, vec![0, 1, 2]);
        assert_eq!(plan.merged, 2);
    }

    #[test]
    fn seed_and_samples_stay_distinct_jobs_but_one_class() {
        // Same class (design + workload kind + backend + batch), three
        // distinct exact keys: three groups, zero merged, and the class
        // key is identical for all — they dispatch consecutively.
        let jobs = vec![mc(8, 3, 100, 1), mc(8, 3, 100, 2), mc(8, 3, 200, 1)];
        let plan = plan(&jobs, "cpu", 4096);
        assert_eq!(plan.groups.len(), 3);
        assert_eq!(plan.merged, 0);
        let classes: Vec<String> =
            jobs.iter().map(|j| class_key(j, "cpu", 4096)).collect();
        assert_eq!(classes[0], classes[1]);
        assert_eq!(classes[0], classes[2]);
    }

    #[test]
    fn class_key_separates_design_backend_batch_and_kind() {
        let a = mc(8, 3, 100, 1);
        assert_ne!(class_key(&a, "cpu", 4096), class_key(&mc(8, 4, 100, 1), "cpu", 4096));
        assert_ne!(class_key(&a, "cpu", 4096), class_key(&a, "pjrt", 4096));
        assert_ne!(class_key(&a, "cpu", 4096), class_key(&a, "cpu", 8192));
        let ex = EvalJob::exhaustive(8, 3, false);
        assert_ne!(class_key(&a, "cpu", 4096), class_key(&ex, "cpu", 4096));
    }

    #[test]
    fn canonical_designs_coalesce_across_spellings() {
        // t=0 segmented is canonically the accurate design: identical
        // exhaustive workloads coalesce into one evaluation.
        let a = EvalJob::exhaustive(8, 0, true);
        let b = EvalJob::exhaustive(8, 0, false);
        let plan = plan(&[a, b], "cpu", 4096);
        assert_eq!(plan.groups.len(), 1);
        assert_eq!(plan.merged, 1);
    }

    #[test]
    fn group_order_clusters_classes_by_first_arrival() {
        let jobs = vec![
            mc(8, 3, 100, 1), // class A
            mc(8, 5, 100, 1), // class B
            mc(8, 3, 100, 2), // class A again, distinct key
        ];
        let plan = plan(&jobs, "cpu", 4096);
        assert_eq!(plan.groups.len(), 3);
        // Class A's two jobs dispatch consecutively.
        assert_eq!(plan.groups[0].requests, vec![0]);
        assert_eq!(plan.groups[1].requests, vec![2]);
        assert_eq!(plan.groups[2].requests, vec![1]);
    }
}
