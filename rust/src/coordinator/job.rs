//! Job and result types for the evaluation service.

use std::time::Duration;

use crate::error::SegmulError;
use crate::error::metrics::{ErrorMetrics, ErrorStats};
use crate::multiplier::MultiplierSpec;

/// Workload specification for one evaluation job.
#[derive(Clone, Debug)]
pub enum WorkSpec {
    /// All `2^(2n)` input pairs (n ≤ 16; practical n ≤ 12 on one core).
    Exhaustive,
    /// Fixed-budget Monte-Carlo with uniform operands.
    MonteCarlo { samples: u64, seed: u64 },
    /// Adaptive Monte-Carlo: stop when the relative CI target on ER is met
    /// (see [`super::convergence`]) or `max_samples` is exhausted.
    Adaptive { max_samples: u64, seed: u64, target_rel_stderr: f64 },
}

/// One evaluation request: a design under a workload. Any
/// [`MultiplierSpec`] — the paper's segmented multiplier, the accurate
/// reference, the related-work baselines, the bit-level oracle, or the
/// netlist simulator — runs through the same drivers, shard pool, and
/// cache.
#[derive(Clone, Debug)]
pub struct EvalJob {
    /// The multiplier design under evaluation.
    pub design: MultiplierSpec,
    /// The workload (exhaustive / Monte-Carlo / adaptive).
    pub spec: WorkSpec,
}

/// Canonical cache identity of a job. Two jobs with equal keys produce
/// identical [`ErrorStats`] **when evaluated through the same backend
/// factory**: the MC operand multiset additionally depends on the
/// backend's batch size (it fixes the chunk-to-stream layout), so this
/// key is only valid within one runner — never persist it across
/// backends. [`super::sweep::SweepRunner`] holds one factory for its
/// whole lifetime, which is what makes its cache sound.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct JobKey {
    /// The canonical design representative (see
    /// [`MultiplierSpec::canonical`]): specs computing the same product
    /// function share one entry.
    pub design: MultiplierSpec,
    /// Hashable image of the workload.
    pub spec: SpecKey,
}

/// Hashable image of [`WorkSpec`] (the adaptive target is keyed by its
/// exact f64 bit pattern).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub enum SpecKey {
    /// All `2^{2n}` input pairs.
    Exhaustive,
    /// Sampled workload, keyed by its exact budget and seed.
    MonteCarlo { samples: u64, seed: u64 },
    /// Adaptive workload (`target_bits` = the f64 target's bit pattern).
    Adaptive { max_samples: u64, seed: u64, target_bits: u64 },
}

impl EvalJob {
    /// Pair `design` with `spec`; bounds are checked at [`Self::validate`].
    pub fn new(design: MultiplierSpec, spec: WorkSpec) -> Self {
        EvalJob { design, spec }
    }

    /// Monte-Carlo job for the paper's segmented design (back-compat
    /// shorthand; use [`EvalJob::new`] for other designs).
    pub fn mc(n: u32, t: u32, fix: bool, samples: u64, seed: u64) -> Self {
        EvalJob {
            design: MultiplierSpec::Segmented { n, t, fix },
            spec: WorkSpec::MonteCarlo { samples, seed },
        }
    }

    /// Exhaustive job for the paper's segmented design.
    pub fn exhaustive(n: u32, t: u32, fix: bool) -> Self {
        EvalJob { design: MultiplierSpec::Segmented { n, t, fix }, spec: WorkSpec::Exhaustive }
    }

    /// Operand bit-width of the design under evaluation.
    pub fn n(&self) -> u32 {
        self.design.n()
    }

    /// The job's cache key: the canonical design representative plus the
    /// workload. `t = 0` segmented configurations collapse across fix
    /// modes *and* onto the accurate design — the zero-bit LSP adder can
    /// never raise the carry that fix-to-1 compensates, so all three
    /// describe the same product function (see
    /// [`MultiplierSpec::canonical`]).
    pub fn key(&self) -> JobKey {
        let spec = match &self.spec {
            WorkSpec::Exhaustive => SpecKey::Exhaustive,
            WorkSpec::MonteCarlo { samples, seed } => {
                SpecKey::MonteCarlo { samples: *samples, seed: *seed }
            }
            WorkSpec::Adaptive { max_samples, seed, target_rel_stderr } => SpecKey::Adaptive {
                max_samples: *max_samples,
                seed: *seed,
                target_bits: target_rel_stderr.to_bits(),
            },
        };
        JobKey { design: self.design.canonical(), spec }
    }

    /// Typed validation of the bounds every driver path relies on.
    pub fn validate(&self) -> Result<(), SegmulError> {
        self.design.validate()?;
        match &self.spec {
            WorkSpec::Exhaustive => {
                if self.n() > 16 {
                    return Err(SegmulError::workload(format!(
                        "exhaustive limited to n <= 16 (n={})",
                        self.n()
                    )));
                }
            }
            WorkSpec::MonteCarlo { samples, .. } => {
                if *samples == 0 {
                    return Err(SegmulError::workload("samples must be positive"));
                }
            }
            WorkSpec::Adaptive { max_samples, target_rel_stderr, .. } => {
                if *max_samples == 0 || *target_rel_stderr <= 0.0 || target_rel_stderr.is_nan() {
                    return Err(SegmulError::workload(format!(
                        "bad adaptive spec (max_samples={max_samples}, target={target_rel_stderr})"
                    )));
                }
            }
        }
        Ok(())
    }
}

/// Completed job output.
#[derive(Clone, Debug)]
pub struct JobResult {
    /// The job as evaluated.
    pub job: EvalJob,
    /// Accumulated error statistics.
    pub stats: ErrorStats,
    /// Backend that executed the job ("cpu" / "pjrt").
    pub backend: &'static str,
    /// Wall time of the evaluation.
    pub wall: Duration,
    /// Backend batch executions performed.
    pub batches: u64,
}

impl JobResult {
    /// Derived metric set. Errs (typed `Stats`) only if the accumulator
    /// is empty — impossible for results produced by the drivers, which
    /// validate the workload to be non-empty before evaluating.
    pub fn metrics(&self) -> Result<ErrorMetrics, SegmulError> {
        self.stats.metrics()
    }

    /// Evaluated pairs per second.
    pub fn throughput(&self) -> f64 {
        self.stats.count as f64 / self.wall.as_secs_f64().max(1e-9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validation() {
        assert!(EvalJob::mc(8, 4, true, 100, 1).validate().is_ok());
        assert!(EvalJob::mc(8, 8, true, 100, 1).validate().is_err());
        assert!(EvalJob::mc(40, 4, true, 100, 1).validate().is_err());
        assert!(EvalJob::exhaustive(18, 4, true).validate().is_err());
        assert!(EvalJob::mc(8, 4, true, 0, 1).validate().is_err());
        let bad = EvalJob {
            design: MultiplierSpec::Segmented { n: 8, t: 1, fix: false },
            spec: WorkSpec::Adaptive { max_samples: 0, seed: 1, target_rel_stderr: 0.1 },
        };
        assert!(bad.validate().is_err());
        // Typed error classes on the public surface.
        assert_eq!(EvalJob::mc(8, 8, true, 100, 1).validate().unwrap_err().kind(), "spec");
        assert_eq!(EvalJob::mc(8, 4, true, 0, 1).validate().unwrap_err().kind(), "workload");
    }

    #[test]
    fn cache_key_identity() {
        // Same job => same key; different seed/samples/config => different.
        assert_eq!(EvalJob::mc(8, 4, true, 100, 1).key(), EvalJob::mc(8, 4, true, 100, 1).key());
        assert_ne!(EvalJob::mc(8, 4, true, 100, 1).key(), EvalJob::mc(8, 4, true, 100, 2).key());
        assert_ne!(EvalJob::mc(8, 4, true, 100, 1).key(), EvalJob::mc(8, 4, true, 200, 1).key());
        assert_ne!(EvalJob::mc(8, 4, true, 100, 1).key(), EvalJob::mc(8, 3, true, 100, 1).key());
        assert_ne!(
            EvalJob::exhaustive(8, 4, true).key(),
            EvalJob::mc(8, 4, true, 100, 1).key()
        );
        // Cross-design keys are distinct for distinct product functions.
        let mc = WorkSpec::MonteCarlo { samples: 100, seed: 1 };
        assert_ne!(
            EvalJob::new(MultiplierSpec::Mitchell { n: 8 }, mc.clone()).key(),
            EvalJob::new(MultiplierSpec::Kulkarni { n: 8 }, mc.clone()).key()
        );
        assert_ne!(
            EvalJob::new(MultiplierSpec::Truncated { n: 8, k: 2 }, mc.clone()).key(),
            EvalJob::new(MultiplierSpec::Truncated { n: 8, k: 4 }, mc).key()
        );
    }

    #[test]
    fn cache_key_canonicalizes_fix_at_t0() {
        // t=0 is accurate: fix-to-1 can never trigger, so both variants
        // share one cache identity...
        assert_eq!(EvalJob::exhaustive(8, 0, true).key(), EvalJob::exhaustive(8, 0, false).key());
        // ...which is the accurate design's identity...
        assert_eq!(
            EvalJob::exhaustive(8, 0, true).key(),
            EvalJob::new(MultiplierSpec::Accurate { n: 8 }, WorkSpec::Exhaustive).key()
        );
        // ...but at t>0 fix is a real configuration axis.
        assert_ne!(EvalJob::exhaustive(8, 4, true).key(), EvalJob::exhaustive(8, 4, false).key());
    }
}
