//! Job and result types for the evaluation service.

use std::time::Duration;

use crate::error::metrics::{ErrorMetrics, ErrorStats};

/// Workload specification for one evaluation job.
#[derive(Clone, Debug)]
pub enum WorkSpec {
    /// All `2^(2n)` input pairs (n ≤ 16; practical n ≤ 12 on one core).
    Exhaustive,
    /// Fixed-budget Monte-Carlo with uniform operands.
    MonteCarlo { samples: u64, seed: u64 },
    /// Adaptive Monte-Carlo: stop when the relative CI target on ER is met
    /// (see [`super::convergence`]) or `max_samples` is exhausted.
    Adaptive { max_samples: u64, seed: u64, target_rel_stderr: f64 },
}

/// One evaluation request.
#[derive(Clone, Debug)]
pub struct EvalJob {
    /// Operand bit-width (must have a lowered artifact for the PJRT path).
    pub n: u32,
    /// Splitting point, `0 <= t < n`; 0 = accurate.
    pub t: u32,
    /// Enable fix-to-1 compensation.
    pub fix: bool,
    pub spec: WorkSpec,
}

impl EvalJob {
    pub fn mc(n: u32, t: u32, fix: bool, samples: u64, seed: u64) -> Self {
        EvalJob { n, t, fix, spec: WorkSpec::MonteCarlo { samples, seed } }
    }

    pub fn exhaustive(n: u32, t: u32, fix: bool) -> Self {
        EvalJob { n, t, fix, spec: WorkSpec::Exhaustive }
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.n >= 1 && self.n <= 32, "n={} out of range", self.n);
        anyhow::ensure!(self.t < self.n, "t={} out of range for n={}", self.t, self.n);
        match &self.spec {
            WorkSpec::Exhaustive => {
                anyhow::ensure!(self.n <= 16, "exhaustive limited to n <= 16 (n={})", self.n)
            }
            WorkSpec::MonteCarlo { samples, .. } => {
                anyhow::ensure!(*samples > 0, "samples must be positive")
            }
            WorkSpec::Adaptive { max_samples, target_rel_stderr, .. } => {
                anyhow::ensure!(*max_samples > 0 && *target_rel_stderr > 0.0, "bad adaptive spec")
            }
        }
        Ok(())
    }
}

/// Completed job output.
#[derive(Clone, Debug)]
pub struct JobResult {
    pub job: EvalJob,
    pub stats: ErrorStats,
    /// Backend that executed the job ("cpu" / "pjrt").
    pub backend: &'static str,
    pub wall: Duration,
    /// Backend batch executions performed.
    pub batches: u64,
}

impl JobResult {
    pub fn metrics(&self) -> ErrorMetrics {
        self.stats.metrics()
    }

    /// Evaluated pairs per second.
    pub fn throughput(&self) -> f64 {
        self.stats.count as f64 / self.wall.as_secs_f64().max(1e-9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validation() {
        assert!(EvalJob::mc(8, 4, true, 100, 1).validate().is_ok());
        assert!(EvalJob::mc(8, 8, true, 100, 1).validate().is_err());
        assert!(EvalJob::mc(40, 4, true, 100, 1).validate().is_err());
        assert!(EvalJob::exhaustive(18, 4, true).validate().is_err());
        assert!(EvalJob::mc(8, 4, true, 0, 1).validate().is_err());
        let bad = EvalJob {
            n: 8,
            t: 1,
            fix: false,
            spec: WorkSpec::Adaptive { max_samples: 0, seed: 1, target_rel_stderr: 0.1 },
        };
        assert!(bad.validate().is_err());
    }
}
