//! Job driver: decomposes a job's sample space into backend-sized batches.
//!
//! The decomposition lives in [`ChunkPlan`]: one deterministic mapping
//! from (job, batch size) to operand chunks, shared by this sequential
//! driver and the sharded parallel runner ([`super::sharded`]) so both
//! see identical operands per chunk id. The Monte-Carlo decomposition
//! (chunk ids → xoshiro streams) is the same one `error::montecarlo`
//! uses, so for a given (seed, chunk) layout the CPU word-level path, the
//! PJRT path, and the standalone `mc_stats` all see identical operands
//! and produce identical integer statistics.

use std::time::Instant;

use anyhow::Result;

use crate::error::metrics::ErrorStats;
use crate::util::rng::Xoshiro256;

use super::backend::EvalBackend;
use super::convergence::Convergence;
use super::job::{EvalJob, JobResult, WorkSpec};

/// Fill operand buffers for MC chunk `chunk_id`.
fn fill_mc_chunk(n: u32, seed: u64, chunk_id: u64, len: usize, a: &mut Vec<u64>, b: &mut Vec<u64>) {
    let mut rng = Xoshiro256::stream(seed, chunk_id);
    a.clear();
    b.clear();
    for _ in 0..len {
        a.push(rng.next_bits(n));
        b.push(rng.next_bits(n));
    }
}

/// Fill operand buffers for exhaustive indices `[start, end)`.
fn fill_exhaustive(n: u32, start: u64, end: u64, a: &mut Vec<u64>, b: &mut Vec<u64>) {
    let mask = (1u64 << n) - 1;
    a.clear();
    b.clear();
    for idx in start..end {
        a.push(idx & mask);
        b.push(idx >> n);
    }
}

/// The deterministic chunk decomposition of one job for a given backend
/// batch size. Chunk `i` always denotes the same operand set — exhaustive
/// index range `[i·chunk, (i+1)·chunk)` or MC stream `i` of the job's
/// seed — regardless of which worker evaluates it or in which order.
#[derive(Clone, Debug)]
pub struct ChunkPlan {
    n: u32,
    spec: WorkSpec,
    /// Pairs per chunk (= the backend batch size).
    chunk: u64,
    /// Total pairs in the job's input space (upper bound for adaptive).
    total: u64,
    n_chunks: u64,
}

impl ChunkPlan {
    /// Plan `job` into backend-batch-sized chunks.
    pub fn new(job: &EvalJob, batch: usize) -> Self {
        let n = job.n();
        let chunk = (batch.max(1)) as u64;
        let total = match &job.spec {
            WorkSpec::Exhaustive => {
                // `EvalJob::validate` enforces this for every driver path;
                // asserted here too so the invariant is local (n = 32
                // would shift-overflow the u64 index space).
                assert!(n <= 16, "exhaustive chunk plan requires n <= 16 (n={n})");
                1u64 << (2 * n)
            }
            WorkSpec::MonteCarlo { samples, .. } => *samples,
            WorkSpec::Adaptive { max_samples, .. } => *max_samples,
        };
        ChunkPlan { n, spec: job.spec.clone(), chunk, total, n_chunks: total.div_ceil(chunk) }
    }

    /// Total chunks in the plan.
    pub fn n_chunks(&self) -> u64 {
        self.n_chunks
    }

    /// Pairs in chunk `chunk_id` (the last chunk may be ragged).
    pub fn chunk_len(&self, chunk_id: u64) -> u64 {
        debug_assert!(chunk_id < self.n_chunks);
        self.chunk.min(self.total - chunk_id * self.chunk)
    }

    /// Convergence policy for adaptive jobs (checked against the in-order
    /// merged prefix after each chunk), `None` for fixed workloads.
    pub fn convergence(&self) -> Option<Convergence> {
        match &self.spec {
            WorkSpec::Adaptive { target_rel_stderr, .. } => {
                Some(Convergence::new(*target_rel_stderr))
            }
            _ => None,
        }
    }

    /// Fill the operand buffers for chunk `chunk_id`.
    pub fn fill(&self, chunk_id: u64, a: &mut Vec<u64>, b: &mut Vec<u64>) {
        debug_assert!(chunk_id < self.n_chunks);
        let len = self.chunk_len(chunk_id);
        match &self.spec {
            WorkSpec::Exhaustive => {
                let start = chunk_id * self.chunk;
                fill_exhaustive(self.n, start, start + len, a, b);
            }
            WorkSpec::MonteCarlo { seed, .. } | WorkSpec::Adaptive { seed, .. } => {
                fill_mc_chunk(self.n, *seed, chunk_id, len as usize, a, b);
            }
        }
    }
}

/// Execute `job` on `backend`, batching as needed.
pub fn run_job(backend: &mut dyn EvalBackend, job: &EvalJob) -> Result<JobResult> {
    job.validate()?;
    anyhow::ensure!(
        backend.supports(job.n()),
        "backend {} does not support n={}",
        backend.name(),
        job.n()
    );
    anyhow::ensure!(
        backend.supports_design(&job.design),
        "backend {} does not support design {}",
        backend.name(),
        job.design.name()
    );
    let started = Instant::now();
    let plan = ChunkPlan::new(job, backend.max_batch());
    let conv = plan.convergence();
    let mut total = ErrorStats::new(job.n());
    let mut batches = 0u64;
    let mut a = Vec::with_capacity(backend.max_batch());
    let mut b = Vec::with_capacity(backend.max_batch());

    for chunk_id in 0..plan.n_chunks() {
        plan.fill(chunk_id, &mut a, &mut b);
        total.merge(&backend.eval_design(&job.design, &a, &b)?);
        batches += 1;
        if let Some(c) = &conv {
            if c.converged(&total) {
                break;
            }
        }
    }

    Ok(JobResult {
        job: job.clone(),
        stats: total,
        backend: backend.name(),
        wall: started.elapsed(),
        batches,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::CpuBackend;
    use crate::error::exhaustive::exhaustive_stats;
    use crate::error::montecarlo::{mc_stats, McConfig};

    #[test]
    fn exhaustive_job_matches_direct_evaluator() {
        let mut be = CpuBackend::new();
        let r = run_job(&mut be, &EvalJob::exhaustive(8, 4, true)).unwrap();
        let direct = exhaustive_stats(8, 4, true);
        assert!(r.stats.approx_eq(&direct));
        assert_eq!(r.backend, "cpu");
        assert_eq!(r.stats.count, 1 << 16);
    }

    #[test]
    fn mc_job_matches_mc_stats_decomposition() {
        // Same seed + same chunk size => identical integer statistics.
        let mut be = CpuBackend::new();
        let r = run_job(&mut be, &EvalJob::mc(8, 3, false, 200_000, 42)).unwrap();
        let mut cfg = McConfig::uniform(200_000, 42);
        cfg.chunk = be.max_batch() as u64;
        let direct = mc_stats(8, 3, false, &cfg);
        assert!(r.stats.approx_eq(&direct));
    }

    #[test]
    fn adaptive_stops_early() {
        let mut be = CpuBackend::new();
        let job = EvalJob {
            design: crate::multiplier::MultiplierSpec::Segmented { n: 8, t: 4, fix: true },
            spec: WorkSpec::Adaptive {
                max_samples: 1 << 24,
                seed: 7,
                target_rel_stderr: 0.05,
            },
        };
        let r = run_job(&mut be, &job).unwrap();
        assert!(r.stats.count < 1 << 24, "should stop before max samples");
        assert!(Convergence::new(0.05).converged(&r.stats));
    }

    #[test]
    fn batch_count_accounting() {
        let mut be = CpuBackend::new();
        let r = run_job(&mut be, &EvalJob::mc(8, 2, false, 100_000, 1)).unwrap();
        assert_eq!(r.batches, (100_000u64).div_ceil(be.max_batch() as u64));
        assert_eq!(r.stats.count, 100_000);
        assert!(r.throughput() > 0.0);
    }

    #[test]
    fn invalid_job_rejected() {
        let mut be = CpuBackend::new();
        assert!(run_job(&mut be, &EvalJob::mc(8, 9, false, 10, 1)).is_err());
    }

    #[test]
    fn chunk_plan_covers_space_exactly() {
        for (job, want_total) in [
            (EvalJob::exhaustive(6, 3, true), 1u64 << 12),
            (EvalJob::mc(8, 2, false, 100_001, 1), 100_001),
        ] {
            let plan = ChunkPlan::new(&job, 1000);
            let total: u64 = (0..plan.n_chunks()).map(|i| plan.chunk_len(i)).sum();
            assert_eq!(total, want_total);
        }
    }

    #[test]
    fn chunk_plan_fill_matches_sequential_space() {
        // Concatenating the chunks re-creates the exhaustive index space.
        let job = EvalJob::exhaustive(5, 2, false);
        let plan = ChunkPlan::new(&job, 300);
        let (mut a, mut b) = (Vec::new(), Vec::new());
        let mut seen = Vec::new();
        for id in 0..plan.n_chunks() {
            plan.fill(id, &mut a, &mut b);
            assert_eq!(a.len() as u64, plan.chunk_len(id));
            for (&x, &y) in a.iter().zip(&b) {
                seen.push((y << 5) | x);
            }
        }
        let want: Vec<u64> = (0..1u64 << 10).collect();
        assert_eq!(seen, want);
    }
}
