//! Job driver: decomposes a job's sample space into backend-sized batches.
//!
//! The Monte-Carlo decomposition (chunk ids → xoshiro streams) is the same
//! one `error::montecarlo` uses, so for a given (seed, chunk) layout the
//! CPU word-level path, the PJRT path, and the standalone `mc_stats` all
//! see identical operands and produce identical integer statistics.

use std::time::Instant;

use anyhow::Result;

use crate::error::metrics::ErrorStats;
use crate::util::rng::Xoshiro256;

use super::backend::EvalBackend;
use super::convergence::Convergence;
use super::job::{EvalJob, JobResult, WorkSpec};

/// Fill operand buffers for MC chunk `chunk_id`.
fn fill_mc_chunk(n: u32, seed: u64, chunk_id: u64, len: usize, a: &mut Vec<u64>, b: &mut Vec<u64>) {
    let mut rng = Xoshiro256::stream(seed, chunk_id);
    a.clear();
    b.clear();
    for _ in 0..len {
        a.push(rng.next_bits(n));
        b.push(rng.next_bits(n));
    }
}

/// Fill operand buffers for exhaustive indices `[start, end)`.
fn fill_exhaustive(n: u32, start: u64, end: u64, a: &mut Vec<u64>, b: &mut Vec<u64>) {
    let mask = (1u64 << n) - 1;
    a.clear();
    b.clear();
    for idx in start..end {
        a.push(idx & mask);
        b.push(idx >> n);
    }
}

/// Execute `job` on `backend`, batching as needed.
pub fn run_job(backend: &mut dyn EvalBackend, job: &EvalJob) -> Result<JobResult> {
    job.validate()?;
    anyhow::ensure!(
        backend.supports(job.n),
        "backend {} does not support n={}",
        backend.name(),
        job.n
    );
    let started = Instant::now();
    let batch = backend.max_batch();
    let mut total = ErrorStats::new(job.n);
    let mut batches = 0u64;
    let mut a = Vec::with_capacity(batch);
    let mut b = Vec::with_capacity(batch);

    match &job.spec {
        WorkSpec::Exhaustive => {
            let space = 1u64 << (2 * job.n);
            let mut start = 0u64;
            while start < space {
                let end = (start + batch as u64).min(space);
                fill_exhaustive(job.n, start, end, &mut a, &mut b);
                total.merge(&backend.eval_batch(job.n, job.t, job.fix, &a, &b)?);
                batches += 1;
                start = end;
            }
        }
        WorkSpec::MonteCarlo { samples, seed } => {
            let n_chunks = samples.div_ceil(batch as u64);
            for chunk_id in 0..n_chunks {
                let len = (batch as u64).min(samples - chunk_id * batch as u64) as usize;
                fill_mc_chunk(job.n, *seed, chunk_id, len, &mut a, &mut b);
                total.merge(&backend.eval_batch(job.n, job.t, job.fix, &a, &b)?);
                batches += 1;
            }
        }
        WorkSpec::Adaptive { max_samples, seed, target_rel_stderr } => {
            let conv = Convergence::new(*target_rel_stderr);
            let n_chunks = max_samples.div_ceil(batch as u64);
            for chunk_id in 0..n_chunks {
                let len = (batch as u64).min(max_samples - chunk_id * batch as u64) as usize;
                fill_mc_chunk(job.n, *seed, chunk_id, len, &mut a, &mut b);
                total.merge(&backend.eval_batch(job.n, job.t, job.fix, &a, &b)?);
                batches += 1;
                if conv.converged(&total) {
                    break;
                }
            }
        }
    }

    Ok(JobResult {
        job: job.clone(),
        stats: total,
        backend: backend.name(),
        wall: started.elapsed(),
        batches,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::CpuBackend;
    use crate::error::exhaustive::exhaustive_stats;
    use crate::error::montecarlo::{mc_stats, McConfig};

    #[test]
    fn exhaustive_job_matches_direct_evaluator() {
        let mut be = CpuBackend::new();
        let r = run_job(&mut be, &EvalJob::exhaustive(8, 4, true)).unwrap();
        let direct = exhaustive_stats(8, 4, true);
        assert!(r.stats.approx_eq(&direct));
        assert_eq!(r.backend, "cpu");
        assert_eq!(r.stats.count, 1 << 16);
    }

    #[test]
    fn mc_job_matches_mc_stats_decomposition() {
        // Same seed + same chunk size => identical integer statistics.
        let mut be = CpuBackend::new();
        let r = run_job(&mut be, &EvalJob::mc(8, 3, false, 200_000, 42)).unwrap();
        let mut cfg = McConfig::uniform(200_000, 42);
        cfg.chunk = be.max_batch() as u64;
        let direct = mc_stats(8, 3, false, &cfg);
        assert!(r.stats.approx_eq(&direct));
    }

    #[test]
    fn adaptive_stops_early() {
        let mut be = CpuBackend::new();
        let job = EvalJob {
            n: 8,
            t: 4,
            fix: true,
            spec: WorkSpec::Adaptive {
                max_samples: 1 << 24,
                seed: 7,
                target_rel_stderr: 0.05,
            },
        };
        let r = run_job(&mut be, &job).unwrap();
        assert!(r.stats.count < 1 << 24, "should stop before max samples");
        assert!(Convergence::new(0.05).converged(&r.stats));
    }

    #[test]
    fn batch_count_accounting() {
        let mut be = CpuBackend::new();
        let r = run_job(&mut be, &EvalJob::mc(8, 2, false, 100_000, 1)).unwrap();
        assert_eq!(r.batches, (100_000u64).div_ceil(be.max_batch() as u64));
        assert_eq!(r.stats.count, 100_000);
        assert!(r.throughput() > 0.0);
    }

    #[test]
    fn invalid_job_rejected() {
        let mut be = CpuBackend::new();
        assert!(run_job(&mut be, &EvalJob::mc(8, 9, false, 10, 1)).is_err());
    }
}
