//! The persistent shard pool: long-lived worker threads that own a
//! backend **across jobs**.
//!
//! [`super::sharded::run_job_sharded`] spawns scoped workers per job and
//! has each build a fresh backend — trivial for the CPU backend, but a
//! real cost for artifact-heavy backends (PJRT executable loading,
//! netlist construction) and the reason the ROADMAP called for a
//! persistent pool. [`WorkerPool`] moves the worker lifetime up to the
//! session: N threads are spawned once, each constructs its backend
//! in-thread exactly once (the PJRT FFI types are not `Send`, so the
//! backend can never migrate out), and every submitted job is broadcast
//! to all of them. Workers steal chunks from the job's shared atomic
//! cursor exactly as the scoped runner does, and the submitting thread
//! folds the per-chunk results through the same in-order merge
//! ([`super::sharded::merge_chunk_stream`]) — so pool results are
//! **bit-identical** to both the scoped sharded runner and the
//! sequential driver, for any worker count and completion schedule.
//!
//! Construction counting is observable ([`WorkerPool::backend_builds`]):
//! a session that runs a thousand jobs still reports exactly
//! `pool_size()` builds, which is the facade's per-worker-per-session
//! contract (`tests/api_facade.rs` proves it with a counting factory).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::error::metrics::ErrorStats;
use crate::error::stream::OrderedMerger;
use crate::error::SegmulError;
use crate::fault::{FaultInjector, FaultSite, RetryCounters, RetryPolicy};
use crate::multiplier::DispatchClass;

use super::backend::EvalBackend;
use super::driver::ChunkPlan;
use super::job::{EvalJob, JobResult};
use super::sharded::{finish_merge, merge_chunk_stream, ChunkEvent};

/// Shared per-job scheduling state (one per submitted job; workers hold
/// an `Arc` until their chunk loop for that job ends).
struct ActiveJob {
    job: EvalJob,
    plan: ChunkPlan,
    n_chunks: u64,
    /// Next unclaimed chunk id.
    next: AtomicU64,
    /// Raised by the merge loop on convergence / failure: workers stop
    /// claiming chunks.
    stop: AtomicBool,
}

enum Request {
    /// Evaluate chunks of this job, streaming `(chunk id, stats)` back
    /// over the provided sender.
    Run(Arc<ActiveJob>, Sender<(u64, Result<ErrorStats>)>),
    /// Capability preflight: can the worker's backend run this job?
    /// (The submitting thread holds no backend — PJRT handles are not
    /// `Send` — so support questions round-trip to a worker.)
    Probe(EvalJob, Sender<Result<(), SegmulError>>),
    /// Collect the worker backend's kernel-dispatch log (which designs
    /// ran on a true batch kernel vs a per-pair scalar fallback).
    Dispatch(Sender<Vec<(String, DispatchClass)>>),
    Shutdown,
}

/// Evaluate one chunk with the worker's self-healing loop: fault seams
/// fire first (injected hangs and delays only stall; injected panics and
/// backend failures are *real* failures taking the real recovery path),
/// then the evaluation runs under `catch_unwind` so a panicking backend
/// kills the attempt, not the worker thread. Failed attempts retry under
/// [`RetryPolicy::chunk`] — the chunk's inputs were filled before the
/// loop and a retry re-evaluates exactly the same pairs, so a recovered
/// chunk is bit-identical to a first-try one. An exhausted budget
/// surfaces the error to the merge, which fails the job loudly: degraded
/// never means silently wrong.
///
/// `AssertUnwindSafe` is a judgment call: the injected panic fires before
/// the backend is touched, and the real backends keep no partial state
/// across `eval_design` calls (the CPU backend is stateless per batch;
/// PJRT buffers are rebuilt per call).
fn eval_chunk_resilient(
    backend: &mut Box<dyn EvalBackend>,
    shared: &ActiveJob,
    a: &[u64],
    b: &[u64],
    faults: &FaultInjector,
    retry: &RetryCounters,
) -> Result<ErrorStats> {
    RetryPolicy::chunk().run(retry, |_attempt| {
        if faults.fire(FaultSite::WorkerHang) {
            std::thread::sleep(Duration::from_millis(50));
        }
        if faults.fire(FaultSite::WorkerDelay) {
            std::thread::sleep(Duration::from_millis(2));
        }
        catch_unwind(AssertUnwindSafe(|| {
            if faults.fire(FaultSite::WorkerPanic) {
                panic!("injected worker panic");
            }
            if faults.fire(FaultSite::BackendFail) {
                return Err(anyhow!("injected transient backend failure"));
            }
            backend.eval_design(&shared.job.design, a, b)
        }))
        .unwrap_or_else(|_| Err(anyhow!("worker panicked evaluating a chunk (caught)")))
    })
}

/// A pool of long-lived executor threads, each owning one backend for the
/// pool's whole lifetime. Jobs are sharded **across** the pool (intra-job
/// parallelism with a deterministic merge); for a pool scheduling whole
/// jobs per worker see [`super::service::EvalService`].
pub struct WorkerPool {
    /// One request channel per worker (jobs are broadcast to all).
    txs: Vec<Sender<Request>>,
    handles: Vec<JoinHandle<()>>,
    /// Batch size reported by the workers' backends (homogeneous: all
    /// workers build from the same factory).
    batch: usize,
    backend_name: &'static str,
    builds: Arc<AtomicU64>,
    faults: Arc<FaultInjector>,
    retry: Arc<RetryCounters>,
}

impl WorkerPool {
    /// Spawn `workers` executor threads. `factory` runs once in each
    /// worker's thread; startup fails if any backend fails to build.
    /// Fault injection is taken from the environment (`SEGMUL_FAULTS`);
    /// use [`Self::start_with_faults`] to pass an explicit injector.
    pub fn start<F>(factory: F, workers: usize) -> Result<WorkerPool>
    where
        F: Fn() -> Result<Box<dyn EvalBackend>> + Send + Sync + 'static,
    {
        Self::start_with_faults(factory, workers, FaultInjector::from_env()?)
    }

    /// [`Self::start`] with an explicit fault injector shared by every
    /// worker (the session wires the same injector through the store and
    /// the pool so telemetry aggregates one account of injected faults).
    pub fn start_with_faults<F>(
        factory: F,
        workers: usize,
        faults: Arc<FaultInjector>,
    ) -> Result<WorkerPool>
    where
        F: Fn() -> Result<Box<dyn EvalBackend>> + Send + Sync + 'static,
    {
        let workers = workers.max(1);
        let factory = Arc::new(factory);
        let builds = Arc::new(AtomicU64::new(0));
        let retry = Arc::new(RetryCounters::new());
        let (ready_tx, ready_rx) = channel::<Result<(usize, &'static str)>>();
        let mut txs = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for i in 0..workers {
            let (tx, rx) = channel::<Request>();
            let factory = factory.clone();
            let builds = builds.clone();
            let ready_tx = ready_tx.clone();
            let faults = faults.clone();
            let retry = retry.clone();
            let handle = std::thread::Builder::new()
                .name(format!("segmul-pool-{i}"))
                .spawn(move || {
                    // Exactly one backend construction per worker, for
                    // the lifetime of the pool.
                    let mut backend = match factory() {
                        Ok(b) => {
                            builds.fetch_add(1, Ordering::SeqCst);
                            let _ = ready_tx.send(Ok((b.max_batch(), b.name())));
                            b
                        }
                        Err(e) => {
                            let _ = ready_tx.send(Err(e));
                            return;
                        }
                    };
                    let mut a: Vec<u64> = Vec::new();
                    let mut b: Vec<u64> = Vec::new();
                    loop {
                        match rx.recv() {
                            Err(_) | Ok(Request::Shutdown) => break,
                            Ok(Request::Probe(job, reply)) => {
                                let r = if !backend.supports(job.n()) {
                                    Err(SegmulError::backend(format!(
                                        "backend {} does not support n={}",
                                        backend.name(),
                                        job.n()
                                    )))
                                } else if !backend.supports_design(&job.design) {
                                    Err(SegmulError::backend(format!(
                                        "backend {} does not support design {}",
                                        backend.name(),
                                        job.design.name()
                                    )))
                                } else {
                                    Ok(())
                                };
                                let _ = reply.send(r);
                            }
                            Ok(Request::Dispatch(reply)) => {
                                let _ = reply.send(backend.kernel_dispatch());
                            }
                            Ok(Request::Run(shared, results)) => {
                                while !shared.stop.load(Ordering::Relaxed) {
                                    let id = shared.next.fetch_add(1, Ordering::Relaxed);
                                    if id >= shared.n_chunks {
                                        break;
                                    }
                                    shared.plan.fill(id, &mut a, &mut b);
                                    let r = eval_chunk_resilient(
                                        &mut backend,
                                        &shared,
                                        &a,
                                        &b,
                                        &faults,
                                        &retry,
                                    );
                                    if results.send((id, r)).is_err() {
                                        break; // job decided; stop early
                                    }
                                }
                                // `results` drops here: the merge loop's
                                // receiver unblocks once every worker is
                                // done with this job.
                            }
                        }
                    }
                })?;
            txs.push(tx);
            handles.push(handle);
        }
        drop(ready_tx);
        let mut batch = 0usize;
        let mut backend_name = "";
        for _ in 0..workers {
            // On failure, dropping the channels (and the handles)
            // unblocks the already-started workers, which exit on the
            // closed channel.
            let (b, name) = ready_rx
                .recv()
                .map_err(|_| anyhow!("pool worker died during startup"))??;
            batch = b;
            backend_name = name;
        }
        Ok(WorkerPool { txs, handles, batch, backend_name, builds, faults, retry })
    }

    /// The fault injector shared by every worker (disabled unless armed).
    pub fn faults(&self) -> &Arc<FaultInjector> {
        &self.faults
    }

    /// Retry accounting for the workers' per-chunk self-healing loop.
    pub fn retry_counters(&self) -> &Arc<RetryCounters> {
        &self.retry
    }

    /// Number of executor threads.
    pub fn pool_size(&self) -> usize {
        self.handles.len()
    }

    /// Total backend constructions since startup (the per-worker-per-
    /// session contract: stays equal to [`Self::pool_size`] no matter how
    /// many jobs run).
    pub fn backend_builds(&self) -> u64 {
        self.builds.load(Ordering::SeqCst)
    }

    /// The workers' backend batch size (chunk granularity).
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Name of the backend the workers hold.
    pub fn backend_name(&self) -> &'static str {
        self.backend_name
    }

    /// Union of every worker's kernel-dispatch log: which designs ran on
    /// a true batch kernel, a lowered PJRT module
    /// ([`DispatchClass::Pjrt`]), or a per-pair scalar fallback, in
    /// deterministic (name-sorted) order. A scalar sighting on *any*
    /// worker wins the merge, so a sweep cannot silently regress to
    /// per-pair dispatch on a subset of its workers. (Workers are
    /// homogeneous — one factory per pool — so batched-vs-pjrt never
    /// mixes for one design.)
    pub fn kernel_dispatch(&self) -> Vec<(String, DispatchClass)> {
        let mut merged: std::collections::BTreeMap<String, DispatchClass> =
            std::collections::BTreeMap::new();
        for wtx in &self.txs {
            let (tx, rx) = channel();
            if wtx.send(Request::Dispatch(tx)).is_err() {
                continue;
            }
            if let Ok(log) = rx.recv() {
                for (name, class) in log {
                    let slot = merged.entry(name).or_insert(class);
                    if class == DispatchClass::Scalar {
                        *slot = DispatchClass::Scalar;
                    }
                }
            }
        }
        merged.into_iter().collect()
    }

    /// Validate `job` and check it against a live worker backend (one
    /// message round trip; workers are idle between jobs). Fails fast
    /// with the same wording as the sequential driver's preflight, but
    /// typed — a capability failure is [`SegmulError::Backend`], never a
    /// per-chunk eval error.
    pub fn preflight(&self, job: &EvalJob) -> Result<(), SegmulError> {
        job.validate()?;
        let (tx, rx) = channel();
        let wtx = self
            .txs
            .first()
            .ok_or_else(|| SegmulError::backend("pool has no workers"))?;
        wtx.send(Request::Probe(job.clone(), tx))
            .map_err(|_| SegmulError::backend("pool worker gone"))?;
        rx.recv().map_err(|_| SegmulError::backend("pool worker died during preflight"))?
    }

    /// Execute `job` sharded across the pool's persistent workers.
    pub fn run_job(&self, job: &EvalJob) -> Result<JobResult> {
        self.run_job_observed(job, &mut |_| {})
    }

    /// [`Self::run_job`], streaming one [`ChunkEvent`] per in-order merge
    /// step to `observer` (called on the submitting thread).
    pub fn run_job_observed(
        &self,
        job: &EvalJob,
        observer: &mut dyn FnMut(ChunkEvent),
    ) -> Result<JobResult> {
        self.run_job_checkpointed(job, &[], observer, None)
    }

    /// [`Self::run_job_observed`] with checkpoint/restore.
    ///
    /// `resume` holds the in-order per-chunk stats recovered from a prior
    /// run's chunk journal (entry `i` is chunk `i`). They are re-folded
    /// through the same [`OrderedMerger`] — observer events and adaptive
    /// convergence checks included, exactly as if the chunks had just
    /// been evaluated — before anything is dispatched; the shared chunk
    /// cursor then starts at the first unevaluated chunk. A job whose
    /// prefix already covers the plan (or already satisfies the adaptive
    /// stopping rule) completes without dispatching at all. The result is
    /// therefore **bit-identical** — `sum_red`, `batches` accounting and
    /// all — to an uninterrupted run of the same job.
    ///
    /// `sink` receives every *newly* merged chunk in chunk-id order at
    /// the moment it folds into the prefix (the journaling hook; resumed
    /// chunks are not re-reported — they are already checkpointed).
    pub fn run_job_checkpointed(
        &self,
        job: &EvalJob,
        resume: &[ErrorStats],
        observer: &mut dyn FnMut(ChunkEvent),
        sink: Option<&mut dyn FnMut(u64, &ErrorStats)>,
    ) -> Result<JobResult> {
        self.preflight(job)?;
        let started = Instant::now();
        let plan = ChunkPlan::new(job, self.batch);
        let n_chunks = plan.n_chunks();
        let conv = plan.convergence();
        let mut merger = OrderedMerger::new(job.n());
        let mut converged = false;
        for stats in resume.iter().take(n_chunks as usize) {
            merger.offer(merger.merged(), stats.clone());
            let stepped = merger.step();
            debug_assert!(stepped, "seeded chunks merge in order by construction");
            observer(ChunkEvent {
                merged: merger.merged(),
                n_chunks,
                samples: merger.prefix().count,
            });
            if let Some(c) = conv.as_ref() {
                if c.converged(merger.prefix()) {
                    converged = true;
                    break;
                }
            }
        }
        if !converged && merger.merged() < n_chunks {
            let shared = Arc::new(ActiveJob {
                job: job.clone(),
                plan,
                n_chunks,
                next: AtomicU64::new(merger.merged()),
                stop: AtomicBool::new(false),
            });
            let (tx, rx) = channel::<(u64, Result<ErrorStats>)>();
            for wtx in &self.txs {
                // A worker gone mid-session surfaces as an incomplete
                // merge below, not as a submit error.
                let _ = wtx.send(Request::Run(shared.clone(), tx.clone()));
            }
            drop(tx); // workers hold the remaining senders
            let (m, c) =
                merge_chunk_stream(&rx, merger, n_chunks, conv.as_ref(), &shared.stop, observer, sink)?;
            merger = m;
            converged = c;
        }
        let (stats, batches) = finish_merge(merger, n_chunks, converged)?;
        Ok(JobResult {
            job: job.clone(),
            stats,
            backend: self.backend_name,
            wall: started.elapsed(),
            batches,
        })
    }

    /// Graceful shutdown (also runs on drop).
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        for tx in &self.txs {
            let _ = tx.send(Request::Shutdown);
        }
        self.txs.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::CpuBackend;
    use crate::coordinator::driver::run_job;
    use crate::coordinator::job::WorkSpec;
    use crate::multiplier::MultiplierSpec;

    fn cpu_factory() -> impl Fn() -> Result<Box<dyn EvalBackend>> + Send + Sync + 'static {
        || Ok(Box::new(CpuBackend::new()) as Box<dyn EvalBackend>)
    }

    fn sequential(job: &EvalJob) -> JobResult {
        let mut be = CpuBackend::new();
        run_job(&mut be, job).unwrap()
    }

    #[test]
    fn pool_results_bit_identical_to_sequential() {
        let jobs = [
            EvalJob::exhaustive(10, 4, true),
            EvalJob::mc(12, 5, false, 300_000, 99),
            EvalJob::new(
                MultiplierSpec::Truncated { n: 10, k: 3 },
                WorkSpec::MonteCarlo { samples: 200_000, seed: 3 },
            ),
        ];
        for workers in [1usize, 2, 7] {
            let pool = WorkerPool::start(cpu_factory(), workers).unwrap();
            for job in &jobs {
                let want = sequential(job);
                let got = pool.run_job(job).unwrap();
                assert_eq!(got.stats, want.stats, "workers={workers}");
                assert_eq!(got.batches, want.batches, "workers={workers}");
                assert_eq!(got.backend, "cpu");
            }
            pool.shutdown();
        }
    }

    #[test]
    fn backends_built_once_per_worker_across_jobs() {
        let pool = WorkerPool::start(cpu_factory(), 3).unwrap();
        assert_eq!(pool.pool_size(), 3);
        assert_eq!(pool.backend_builds(), 3);
        for seed in 0..5u64 {
            pool.run_job(&EvalJob::mc(8, 3, true, 100_000, seed)).unwrap();
        }
        assert_eq!(pool.backend_builds(), 3, "persistent workers must not rebuild");
    }

    #[test]
    fn adaptive_same_stopping_point_as_sequential() {
        let job = EvalJob {
            design: MultiplierSpec::Segmented { n: 8, t: 4, fix: true },
            spec: WorkSpec::Adaptive { max_samples: 1 << 24, seed: 7, target_rel_stderr: 0.05 },
        };
        let want = sequential(&job);
        let pool = WorkerPool::start(cpu_factory(), 4).unwrap();
        let got = pool.run_job(&job).unwrap();
        assert_eq!(got.stats, want.stats);
        assert_eq!(got.batches, want.batches);
        // The pool must stay usable after an early-stopped job.
        let again = pool.run_job(&EvalJob::mc(8, 2, false, 100_000, 1)).unwrap();
        assert_eq!(again.stats.count, 100_000);
    }

    #[test]
    fn observer_sees_every_merge_step() {
        let pool = WorkerPool::start(cpu_factory(), 2).unwrap();
        let job = EvalJob::mc(8, 3, true, 300_000, 2);
        let mut events: Vec<ChunkEvent> = Vec::new();
        let r = pool.run_job_observed(&job, &mut |e| events.push(e)).unwrap();
        assert_eq!(events.len() as u64, r.batches);
        // Merged counts are strictly increasing, samples monotone, and
        // the last event covers the full budget.
        for (i, e) in events.iter().enumerate() {
            assert_eq!(e.merged, i as u64 + 1);
            assert_eq!(e.n_chunks, r.batches);
        }
        assert_eq!(events.last().unwrap().samples, 300_000);
    }

    #[test]
    fn checkpointed_resume_is_bit_identical_from_any_prefix() {
        let job = EvalJob::mc(8, 3, true, 300_000, 9);
        let pool = WorkerPool::start(cpu_factory(), 2).unwrap();
        let want = pool.run_job(&job).unwrap();
        // Capture the per-chunk stream through the sink: it must arrive
        // in chunk-id order, one call per folded chunk.
        let mut chunks: Vec<ErrorStats> = Vec::new();
        {
            let mut sink = |id: u64, s: &ErrorStats| {
                assert_eq!(id as usize, chunks.len(), "sink must run in chunk order");
                chunks.push(s.clone());
            };
            pool.run_job_checkpointed(&job, &[], &mut |_| {}, Some(&mut sink)).unwrap();
        }
        assert_eq!(chunks.len() as u64, want.batches);
        // Resuming from any journaled prefix — none, one chunk, half, or
        // the whole plan (which dispatches nothing) — reproduces the
        // uninterrupted result bit for bit.
        for cut in [0usize, 1, chunks.len() / 2, chunks.len()] {
            let got =
                pool.run_job_checkpointed(&job, &chunks[..cut], &mut |_| {}, None).unwrap();
            assert_eq!(got.stats, want.stats, "cut={cut}");
            assert_eq!(got.stats.sum_red.to_bits(), want.stats.sum_red.to_bits(), "cut={cut}");
            assert_eq!(got.batches, want.batches, "cut={cut}");
        }
    }

    #[test]
    fn invalid_jobs_rejected_and_pool_stays_usable() {
        let pool = WorkerPool::start(cpu_factory(), 2).unwrap();
        assert!(pool.run_job(&EvalJob::mc(8, 9, false, 10, 1)).is_err());
        assert!(pool.run_job(&EvalJob::exhaustive(20, 2, false)).is_err());
        let ok = pool.run_job(&EvalJob::mc(8, 2, false, 10_000, 1)).unwrap();
        assert_eq!(ok.stats.count, 10_000);
    }

    #[test]
    fn factory_failure_fails_startup() {
        let r = WorkerPool::start(|| Err(anyhow!("boom")), 3);
        assert!(r.is_err());
    }

    #[test]
    fn preflight_rejects_unsupported_designs_with_typed_backend_error() {
        // A backend on the trait defaults (like PJRT) evaluates only the
        // segmented family; the pool must reject other designs up front
        // — typed, and with the driver's wording — instead of surfacing
        // per-chunk eval errors.
        struct SegOnly;
        impl EvalBackend for SegOnly {
            fn name(&self) -> &'static str {
                "segonly"
            }
            fn max_batch(&self) -> usize {
                256
            }
            fn supports(&self, n: u32) -> bool {
                (1..=32).contains(&n)
            }
            fn eval_batch(
                &mut self,
                n: u32,
                t: u32,
                fix: bool,
                a: &[u64],
                b: &[u64],
            ) -> Result<ErrorStats> {
                CpuBackend::new().eval_batch(n, t, fix, a, b)
            }
        }
        let pool =
            WorkerPool::start(|| Ok(Box::new(SegOnly) as Box<dyn EvalBackend>), 2).unwrap();
        let bad = EvalJob::new(
            MultiplierSpec::Mitchell { n: 8 },
            WorkSpec::MonteCarlo { samples: 100, seed: 1 },
        );
        let e = pool.preflight(&bad).unwrap_err();
        assert_eq!(e.kind(), "backend");
        assert!(e.to_string().contains("mitchell"), "{e}");
        assert!(pool.run_job(&bad).is_err());
        // Segmented (and accurate) jobs still pass the same preflight.
        pool.preflight(&EvalJob::mc(8, 2, true, 1000, 1)).unwrap();
        let ok = pool.run_job(&EvalJob::mc(8, 2, true, 1000, 1)).unwrap();
        assert_eq!(ok.stats.count, 1000);
    }

    #[test]
    fn kernel_dispatch_reports_batch_kernels_across_workers() {
        let pool = WorkerPool::start(cpu_factory(), 3).unwrap();
        assert!(pool.kernel_dispatch().is_empty(), "nothing evaluated yet");
        pool.run_job(&EvalJob::mc(8, 3, true, 200_000, 5)).unwrap();
        pool.run_job(&EvalJob::new(
            MultiplierSpec::Mitchell { n: 8 },
            WorkSpec::MonteCarlo { samples: 200_000, seed: 5 },
        ))
        .unwrap();
        let log = pool.kernel_dispatch();
        // Chunk stealing spreads both jobs over the workers; the union
        // must contain each design exactly once, on a batch kernel.
        assert_eq!(
            log.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>(),
            vec!["mitchell(n=8)", "segmul(n=8,t=3,fix)"]
        );
        for (name, class) in &log {
            assert_eq!(*class, crate::multiplier::DispatchClass::Batched, "{name}");
        }
    }

    #[test]
    fn drop_shuts_down_cleanly() {
        let pool = WorkerPool::start(cpu_factory(), 2).unwrap();
        let _ = pool.run_job(&EvalJob::mc(4, 1, false, 100, 1)).unwrap();
        drop(pool); // must not hang
    }

    #[test]
    fn injected_worker_faults_recover_bit_identically() {
        // Panics, transient backend failures and scheduling delays all
        // fire — and the recovered result is still bit-identical to the
        // sequential driver, because a retried chunk re-evaluates exactly
        // the same input pairs.
        let job = EvalJob::mc(8, 3, true, 300_000, 11);
        let want = sequential(&job);
        let faults = Arc::new(
            FaultInjector::parse(
                "worker.panic:first=2,backend.fail:every=5,worker.delay:every=3",
                0xFA11,
            )
            .unwrap(),
        );
        let pool = WorkerPool::start_with_faults(cpu_factory(), 3, faults.clone()).unwrap();
        let got = pool.run_job(&job).unwrap();
        assert_eq!(got.stats, want.stats);
        assert_eq!(got.stats.sum_red.to_bits(), want.stats.sum_red.to_bits());
        assert_eq!(got.batches, want.batches);
        assert!(faults.total_injected() > 0, "faults must actually fire");
        assert!(faults.injected(FaultSite::WorkerPanic) >= 2);
        assert!(pool.retry_counters().retries() > 0, "recovery goes through the retry loop");
        assert_eq!(pool.retry_counters().gave_up(), 0);
    }

    #[test]
    fn exhausted_retries_fail_the_job_but_never_the_workers() {
        // Every attempt panics: the retry budget exhausts and the job
        // fails loudly — but each panic was caught, so the worker
        // threads survive and keep answering.
        let faults = Arc::new(FaultInjector::parse("worker.panic:p=1", 7).unwrap());
        let pool = WorkerPool::start_with_faults(cpu_factory(), 2, faults.clone()).unwrap();
        let job = EvalJob::mc(8, 3, true, 50_000, 1);
        let err = pool.run_job(&job).unwrap_err();
        assert!(err.to_string().contains("panicked"), "{err}");
        assert!(pool.retry_counters().gave_up() > 0);
        assert!(faults.total_injected() >= 4, "max_attempts panics before giving up");
        // A dead worker could not answer this probe round trip.
        pool.preflight(&job).unwrap();
    }
}
