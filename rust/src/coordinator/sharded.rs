//! Sharded parallel job execution with a deterministic merge.
//!
//! One job's chunk space (MC chunk ids / exhaustive index ranges, as laid
//! out by [`super::driver::ChunkPlan`]) is claimed dynamically by N
//! workers from a shared atomic cursor — idle workers steal the next
//! chunk the moment they finish one, so ragged chunk costs balance
//! automatically. Each worker owns its own backend (PJRT handles are not
//! `Send`; the factory runs in-thread) and streams per-chunk
//! [`ErrorStats`] back over a channel. The receiving side folds them
//! through [`OrderedMerger`] strictly in chunk-id order, which makes the
//! result **bit-identical** — order-sensitive f64 fields included — to a
//! single-worker run, for any worker count and any completion schedule.
//!
//! Adaptive jobs keep the sequential stopping rule: convergence is
//! evaluated on the in-order prefix after every single chunk merge, so
//! the stopping chunk (and therefore the result) is the same whether one
//! worker or sixteen evaluated the stream. Chunks evaluated beyond the
//! stopping point are discarded, never merged.
//!
//! [`run_job_sharded`] spawns scoped workers per job and builds their
//! backends per job — the one-shot path. The persistent
//! [`super::pool::WorkerPool`] reuses the same chunk-steal protocol and
//! the same merge loop ([`merge_chunk_stream`]) over long-lived worker
//! threads that keep a backend across jobs; both therefore produce
//! identical statistics for identical jobs.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::error::metrics::ErrorStats;
use crate::error::stream::OrderedMerger;

use super::backend::EvalBackend;
use super::convergence::Convergence;
use super::driver::{run_job, ChunkPlan};
use super::job::{EvalJob, JobResult};

/// One in-order merge step, streamed to observers: chunk `merged - 1`
/// just folded into the prefix.
#[derive(Clone, Copy, Debug)]
pub struct ChunkEvent {
    /// Chunks folded into the in-order prefix so far.
    pub merged: u64,
    /// Total chunks in the job's plan (adaptive jobs may stop earlier).
    pub n_chunks: u64,
    /// Samples accumulated in the prefix.
    pub samples: u64,
}

/// Fold the per-chunk result stream `rx` in chunk-id order, checking
/// adaptive convergence on every in-order prefix and reporting each merge
/// step to `observer`. Shared by the scoped per-job runner and the
/// persistent worker pool — the merge decision (and therefore the result)
/// is identical wherever the chunks were evaluated. Returns the merger
/// plus whether the adaptive stopping rule fired (`false` for fixed
/// workloads and for adaptive runs that exhausted their budget).
///
/// `merger` is supplied by the caller so a checkpointed run can pre-seed
/// it with journaled chunks (see `WorkerPool::run_job_checkpointed`);
/// the stream then only carries chunk ids from the resumed cursor up.
/// When `sink` is present it receives `(chunk_id, stats)` for every chunk
/// **in merge (= chunk-id) order, at the moment it folds into the
/// prefix** — the chunk-journal hook: a checkpoint written from here is
/// always a valid in-order prefix, whatever instant the process dies.
///
/// Error parity with the sequential driver: a chunk's eval error only
/// fails the job when the in-order prefix actually *needs* that chunk —
/// an adaptive job that converges on earlier chunks returns Ok exactly as
/// a one-worker run would, and with several errored chunks the one
/// sequential execution would hit first (lowest id) is the one reported.
pub(crate) fn merge_chunk_stream(
    rx: &Receiver<(u64, Result<ErrorStats>)>,
    mut merger: OrderedMerger,
    n_chunks: u64,
    conv: Option<&Convergence>,
    stop: &AtomicBool,
    observer: &mut dyn FnMut(ChunkEvent),
    mut sink: Option<&mut dyn FnMut(u64, &ErrorStats)>,
) -> Result<(OrderedMerger, bool)> {
    enum Decision {
        Pending,
        Converged,
        Failed(anyhow::Error),
    }
    let mut chunk_errs: std::collections::BTreeMap<u64, anyhow::Error> =
        std::collections::BTreeMap::new();
    // Side copies for the sink: the merger consumes stats on `step()`,
    // so the journal hook keeps its own pending map (only when a sink is
    // attached; one small clone per chunk).
    let mut sink_pending: std::collections::BTreeMap<u64, ErrorStats> =
        std::collections::BTreeMap::new();
    let mut decision = Decision::Pending;
    while let Ok((id, r)) = rx.recv() {
        if !matches!(decision, Decision::Pending) {
            continue; // draining: result already decided
        }
        match r {
            Err(e) => {
                chunk_errs.entry(id).or_insert(e);
            }
            Ok(s) => {
                if sink.is_some() {
                    sink_pending.insert(id, s.clone());
                }
                merger.offer(id, s);
            }
        }
        // Advance the prefix one chunk at a time so adaptive convergence
        // sees every prefix a sequential run would see, failing the
        // moment the prefix reaches an errored chunk.
        loop {
            if let Some(e) = chunk_errs.remove(&merger.merged()) {
                decision = Decision::Failed(e);
                stop.store(true, Ordering::Relaxed);
                break;
            }
            if !merger.step() {
                break;
            }
            let merged_id = merger.merged() - 1;
            if let Some(sink) = sink.as_mut() {
                if let Some(s) = sink_pending.remove(&merged_id) {
                    sink(merged_id, &s);
                }
            }
            observer(ChunkEvent {
                merged: merger.merged(),
                n_chunks,
                samples: merger.prefix().count,
            });
            if let Some(c) = conv {
                if c.converged(merger.prefix()) {
                    decision = Decision::Converged;
                    stop.store(true, Ordering::Relaxed);
                    break;
                }
            }
        }
    }
    match decision {
        Decision::Failed(e) => Err(e),
        Decision::Converged => Ok((merger, true)),
        Decision::Pending => {
            // Stream ended naturally. An incomplete prefix means an
            // errored chunk (or a failed factory, id = u64::MAX with no
            // worker left to cover the space) blocked it.
            if merger.merged() < n_chunks {
                if let Some((_, e)) = chunk_errs.into_iter().next() {
                    return Err(e);
                }
            }
            Ok((merger, false))
        }
    }
}

/// Turn a finished merger into the job's statistics, with the same
/// accounting as the sequential driver (`batches` counts folded chunks).
/// `converged` is the merge's adaptive stopping decision: without it,
/// every chunk of the plan must have been folded — a worker that died
/// mid-job (dropping its sender without an error result) must fail the
/// job, never silently truncate it.
pub(crate) fn finish_merge(
    merger: OrderedMerger,
    n_chunks: u64,
    converged: bool,
) -> Result<(ErrorStats, u64)> {
    let batches = merger.merged();
    let stats = if converged {
        merger.into_prefix()
    } else {
        anyhow::ensure!(
            merger.merged() == n_chunks,
            "sharded run folded {} of {} chunks",
            merger.merged(),
            n_chunks
        );
        merger.finish()
    };
    if stats.count == 0 {
        return Err(anyhow!("sharded run produced no samples"));
    }
    Ok((stats, batches))
}

/// Execute `job` across `workers` threads, each running a backend built
/// by `factory` in-thread. With `workers == 1` this is exactly
/// [`run_job`]; with more, the chunk-ordered merge keeps the result
/// bit-identical to that sequential run. `JobResult::batches` counts the
/// chunks folded into the result (matching the sequential driver's
/// accounting; an adaptive job may additionally have evaluated and
/// discarded chunks beyond its stopping point).
pub fn run_job_sharded<F>(factory: &F, job: &EvalJob, workers: usize) -> Result<JobResult>
where
    F: Fn() -> Result<Box<dyn EvalBackend>> + Sync,
{
    job.validate()?;
    if workers <= 1 {
        let mut backend = factory()?;
        return run_job(backend.as_mut(), job);
    }
    let started = Instant::now();
    // Probe a backend on the calling thread for the batch size and the
    // support check; workers re-build their own from the same factory.
    let (batch, backend_name) = {
        let probe = factory()?;
        anyhow::ensure!(
            probe.supports(job.n()),
            "backend {} does not support n={}",
            probe.name(),
            job.n()
        );
        anyhow::ensure!(
            probe.supports_design(&job.design),
            "backend {} does not support design {}",
            probe.name(),
            job.design.name()
        );
        (probe.max_batch(), probe.name())
    };
    let plan = ChunkPlan::new(job, batch);
    let n_chunks = plan.n_chunks();
    let workers = workers.min(n_chunks as usize).max(1);
    let conv = plan.convergence();

    // Shared scheduling state: workers steal the next unclaimed chunk id.
    let next = AtomicU64::new(0);
    let stop = AtomicBool::new(false);
    let (tx, rx) = channel::<(u64, Result<ErrorStats>)>();

    let merged: Result<(OrderedMerger, bool)> = std::thread::scope(|scope| {
        for _ in 0..workers {
            let tx = tx.clone();
            let (plan, next, stop) = (&plan, &next, &stop);
            scope.spawn(move || {
                let mut backend = match factory() {
                    Ok(b) => b,
                    Err(e) => {
                        let _ = tx.send((u64::MAX, Err(e)));
                        return;
                    }
                };
                let mut a = Vec::with_capacity(batch);
                let mut b = Vec::with_capacity(batch);
                while !stop.load(Ordering::Relaxed) {
                    let id = next.fetch_add(1, Ordering::Relaxed);
                    if id >= n_chunks {
                        break;
                    }
                    plan.fill(id, &mut a, &mut b);
                    let r = backend.eval_design(&job.design, &a, &b);
                    if tx.send((id, r)).is_err() {
                        break;
                    }
                }
            });
        }
        drop(tx); // workers hold the remaining senders

        merge_chunk_stream(
            &rx,
            OrderedMerger::new(job.n()),
            n_chunks,
            conv.as_ref(),
            &stop,
            &mut |_| {},
            None,
        )
    });
    let (merger, converged) = merged?;
    let (stats, batches) = finish_merge(merger, n_chunks, converged)?;
    Ok(JobResult { job: job.clone(), stats, backend: backend_name, wall: started.elapsed(), batches })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::CpuBackend;
    use crate::coordinator::job::WorkSpec;
    use crate::multiplier::MultiplierSpec;

    fn cpu_factory() -> impl Fn() -> Result<Box<dyn EvalBackend>> + Sync {
        || Ok(Box::new(CpuBackend::new()) as Box<dyn EvalBackend>)
    }

    /// Sequential reference for a job (workers = 1).
    fn sequential(job: &EvalJob) -> JobResult {
        let mut be = CpuBackend::new();
        run_job(&mut be, job).unwrap()
    }

    #[test]
    fn exhaustive_bit_identical_across_worker_counts() {
        // n=10 => 2^20 pairs => 16 chunks of 2^16: enough to interleave.
        let job = EvalJob::exhaustive(10, 4, true);
        let want = sequential(&job);
        for workers in [2usize, 3, 7] {
            let got = run_job_sharded(&cpu_factory(), &job, workers).unwrap();
            // Full equality: integer fields AND the f64 sum_red.
            assert_eq!(got.stats, want.stats, "workers={workers}");
            assert_eq!(got.batches, want.batches);
            assert_eq!(got.backend, "cpu");
        }
    }

    #[test]
    fn mc_bit_identical_across_worker_counts() {
        let job = EvalJob::mc(12, 5, false, 700_000, 99);
        let want = sequential(&job);
        for workers in [2usize, 5] {
            let got = run_job_sharded(&cpu_factory(), &job, workers).unwrap();
            assert_eq!(got.stats, want.stats, "workers={workers}");
        }
    }

    #[test]
    fn non_segmented_design_bit_identical_across_worker_counts() {
        // Cross-design sharding: a related-work baseline runs through the
        // same chunk-steal + ordered-merge path.
        let job = EvalJob::new(
            MultiplierSpec::Mitchell { n: 10 },
            WorkSpec::MonteCarlo { samples: 300_000, seed: 5 },
        );
        let want = sequential(&job);
        for workers in [2usize, 5] {
            let got = run_job_sharded(&cpu_factory(), &job, workers).unwrap();
            assert_eq!(got.stats, want.stats, "workers={workers}");
        }
    }

    #[test]
    fn adaptive_same_stopping_point() {
        let job = EvalJob {
            design: MultiplierSpec::Segmented { n: 8, t: 4, fix: true },
            spec: WorkSpec::Adaptive { max_samples: 1 << 24, seed: 7, target_rel_stderr: 0.05 },
        };
        let want = sequential(&job);
        let got = run_job_sharded(&cpu_factory(), &job, 4).unwrap();
        // Same convergence decision on the same ordered prefixes => the
        // very same chunks are folded, bit-identically.
        assert_eq!(got.stats, want.stats);
        assert_eq!(got.batches, want.batches);
        assert!(got.stats.count < 1 << 24);
    }

    #[test]
    fn single_worker_delegates_to_sequential() {
        let job = EvalJob::mc(8, 3, true, 100_000, 5);
        let want = sequential(&job);
        let got = run_job_sharded(&cpu_factory(), &job, 1).unwrap();
        assert_eq!(got.stats, want.stats);
    }

    #[test]
    fn invalid_job_rejected() {
        assert!(run_job_sharded(&cpu_factory(), &EvalJob::mc(8, 9, false, 10, 1), 4).is_err());
    }

    #[test]
    fn factory_failure_propagates() {
        let bad = || -> Result<Box<dyn EvalBackend>> { Err(anyhow!("no backend")) };
        assert!(run_job_sharded(&bad, &EvalJob::mc(8, 3, false, 10, 1), 3).is_err());
    }

    #[test]
    fn worker_eval_error_propagates() {
        struct Picky;
        impl EvalBackend for Picky {
            fn name(&self) -> &'static str {
                "picky"
            }
            fn max_batch(&self) -> usize {
                64
            }
            fn supports(&self, _n: u32) -> bool {
                true
            }
            fn eval_batch(
                &mut self,
                _n: u32,
                _t: u32,
                _fix: bool,
                _a: &[u64],
                _b: &[u64],
            ) -> Result<ErrorStats> {
                Err(anyhow!("backend exploded"))
            }
        }
        let factory = || -> Result<Box<dyn EvalBackend>> { Ok(Box::new(Picky)) };
        let err = run_job_sharded(&factory, &EvalJob::mc(8, 3, false, 10_000, 1), 3)
            .unwrap_err()
            .to_string();
        assert!(err.contains("exploded"), "{err}");
    }

    #[test]
    fn adaptive_ignores_errors_beyond_its_stopping_chunk() {
        // Backend that only evaluates the job's chunk 0 (recognized by
        // its first operand — MC chunk id determines the rng stream) and
        // errors on every other chunk. Sequential: chunk 0 converges, so
        // chunk 1 is never evaluated => Ok. Sharded workers eagerly
        // evaluate (and fail) later chunks; those errors must be
        // discarded because the converged prefix never needs them.
        use crate::util::rng::Xoshiro256;
        let (n, seed) = (8u32, 11u64);
        let first0 = Xoshiro256::stream(seed, 0).next_bits(n);
        struct Flaky {
            inner: CpuBackend,
            first0: u64,
        }
        impl EvalBackend for Flaky {
            fn name(&self) -> &'static str {
                "flaky"
            }
            fn max_batch(&self) -> usize {
                self.inner.max_batch()
            }
            fn supports(&self, n: u32) -> bool {
                self.inner.supports(n)
            }
            fn eval_batch(
                &mut self,
                n: u32,
                t: u32,
                fix: bool,
                a: &[u64],
                b: &[u64],
            ) -> Result<ErrorStats> {
                if a.first() != Some(&self.first0) {
                    return Err(anyhow!("tail chunk refused"));
                }
                self.inner.eval_batch(n, t, fix, a, b)
            }
        }
        let factory = move || -> Result<Box<dyn EvalBackend>> {
            Ok(Box::new(Flaky { inner: CpuBackend::new(), first0 }))
        };
        let job = EvalJob {
            design: MultiplierSpec::Segmented { n, t: 4, fix: true },
            spec: WorkSpec::Adaptive {
                max_samples: 5 * (1 << 16),
                seed,
                target_rel_stderr: 0.05,
            },
        };
        let want = {
            let mut be = Flaky { inner: CpuBackend::new(), first0 };
            run_job(&mut be, &job).unwrap()
        };
        assert_eq!(want.batches, 1, "test premise: sequential converges on chunk 0");
        let got = run_job_sharded(&factory, &job, 3).unwrap();
        assert_eq!(got.stats, want.stats);
        assert_eq!(got.batches, 1);
        // A fixed-budget job over the same flaky backend must still fail:
        // its prefix needs the refused chunks.
        let fixed = EvalJob::mc(n, 4, true, 5 * (1 << 16), seed);
        let err = run_job_sharded(&factory, &fixed, 3).unwrap_err().to_string();
        assert!(err.contains("refused"), "{err}");
    }
}
