//! L3 coordination: the asynchronous error-evaluation service.
//!
//! The paper's contribution is an arithmetic unit; the system a downstream
//! user adopts around it is an *evaluation platform*: submit
//! (design, workload) jobs — any [`crate::multiplier::MultiplierSpec`],
//! from the paper's segmented multiplier to the related-work baselines,
//! the bit-level oracle, and the gate-level netlist — and get error
//! metrics back, with the heavy batched evaluation running on the
//! AOT-compiled PJRT executables (python never on the request path) and a
//! pure-Rust word-level backend as fallback / cross-check.
//!
//! This module is the machinery layer; the public entry point for
//! library users, the CLI, and benches is the [`crate::api`] facade.
//!
//! * [`job`]         — job/result types and the workload specs
//!   (exhaustive, fixed-budget Monte-Carlo, adaptive CI-targeted MC).
//! * [`backend`]     — the evaluation backends: [`backend::CpuBackend`]
//!   (word-level model + every non-segmented design via cached batch
//!   evaluators) and [`backend::PjrtBackend`] (the compiled stats
//!   modules, with pad-and-correct batching to the lowered batch size).
//! * [`driver`]      — the deterministic chunk decomposition
//!   ([`driver::ChunkPlan`]) and the sequential driver; the MC
//!   decomposition is identical to `error::montecarlo` so CPU and PJRT
//!   paths produce bit-identical integer statistics per seed.
//! * [`sharded`]     — intra-job parallelism: N workers steal chunks
//!   from a shared cursor and an ordered merge keeps results
//!   bit-identical to the sequential driver for any worker count.
//! * [`pool`]        — the persistent shard pool: long-lived worker
//!   threads own one backend each **across jobs** (the facade's session
//!   executor; replaces per-job backend construction).
//! * [`sweep`]       — design-space sweep orchestration over the paper
//!   grid and the cross-design comparative grids, with a canonical
//!   `(design, workload, seed)` result cache, an analytic
//!   answer-source layer serving closed-form grid points in O(1), and
//!   an optional persistent [`crate::store::ResultStore`] making sweeps
//!   checkpointed, resumable, and shardable across processes.
//! * [`convergence`] — CI-based early stopping for adaptive jobs.
//! * [`service`]     — the threaded job service: a pool of executor
//!   threads owns the (non-Send) PJRT runtimes and schedules whole jobs
//!   per worker; clients submit over a shared channel and receive
//!   tickets.

pub mod backend;
pub mod convergence;
pub mod driver;
pub mod job;
pub mod pool;
pub mod service;
pub mod sharded;
pub mod sweep;

pub use backend::{CpuBackend, EvalBackend, PjrtBackend};
pub use convergence::Convergence;
pub use driver::{run_job, ChunkPlan};
pub use job::{EvalJob, JobKey, JobResult, SpecKey, WorkSpec};
pub use pool::WorkerPool;
pub use service::{EvalService, ServiceTelemetry};
pub use sharded::{run_job_sharded, ChunkEvent};
pub use sweep::{analytic_outcome, AnalyticMode, Answer, Shard, SweepGrid, SweepOutcome, SweepRunner};
