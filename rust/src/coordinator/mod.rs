//! L3 coordination: the asynchronous error-evaluation service.
//!
//! The paper's contribution is an arithmetic unit; the system a downstream
//! user adopts around it is an *evaluation platform*: submit
//! (bit-width, splitting point, fix, workload) jobs, get error metrics
//! back, with the heavy batched evaluation running on the AOT-compiled
//! PJRT executables (python never on the request path) and a pure-Rust
//! word-level backend as fallback / cross-check.
//!
//! * [`job`]         — job/result types and the workload specs
//!   (exhaustive, fixed-budget Monte-Carlo, adaptive CI-targeted MC).
//! * [`backend`]     — the evaluation backends: [`backend::CpuBackend`]
//!   (word-level model) and [`backend::PjrtBackend`] (the compiled stats
//!   modules, with pad-and-correct batching to the lowered batch size).
//! * [`driver`]      — the deterministic chunk decomposition
//!   ([`driver::ChunkPlan`]) and the sequential driver; the MC
//!   decomposition is identical to `error::montecarlo` so CPU and PJRT
//!   paths produce bit-identical integer statistics per seed.
//! * [`sharded`]     — intra-job parallelism: N workers steal chunks
//!   from a shared cursor and an ordered merge keeps results
//!   bit-identical to the sequential driver for any worker count.
//! * [`sweep`]       — design-space sweep orchestration over the paper
//!   grid, with a `(config, seed, samples)` result cache.
//! * [`convergence`] — CI-based early stopping for adaptive jobs.
//! * [`service`]     — the threaded service: a pool of executor threads
//!   owns the (non-Send) PJRT runtimes; clients submit jobs over a
//!   shared channel and receive tickets.

pub mod backend;
pub mod convergence;
pub mod driver;
pub mod job;
pub mod service;
pub mod sharded;
pub mod sweep;

pub use backend::{CpuBackend, EvalBackend, PjrtBackend};
pub use convergence::Convergence;
pub use driver::{run_job, ChunkPlan};
pub use job::{EvalJob, JobKey, JobResult, SpecKey, WorkSpec};
pub use service::{EvalService, ServiceTelemetry};
pub use sharded::run_job_sharded;
pub use sweep::{SweepGrid, SweepOutcome, SweepRunner};
