//! L3 coordination: the asynchronous error-evaluation service.
//!
//! The paper's contribution is an arithmetic unit; the system a downstream
//! user adopts around it is an *evaluation platform*: submit
//! (bit-width, splitting point, fix, workload) jobs, get error metrics
//! back, with the heavy batched evaluation running on the AOT-compiled
//! PJRT executables (python never on the request path) and a pure-Rust
//! word-level backend as fallback / cross-check.
//!
//! * [`job`]         — job/result types and the workload specs
//!   (exhaustive, fixed-budget Monte-Carlo, adaptive CI-targeted MC).
//! * [`backend`]     — the evaluation backends: [`backend::CpuBackend`]
//!   (word-level model) and [`backend::PjrtBackend`] (the compiled stats
//!   modules, with pad-and-correct batching to the lowered batch size).
//! * [`driver`]      — chunking/batching of a job onto a backend; the MC
//!   decomposition is identical to `error::montecarlo` so CPU and PJRT
//!   paths produce bit-identical integer statistics per seed.
//! * [`convergence`] — CI-based early stopping for adaptive jobs.
//! * [`service`]     — the threaded service: an executor thread owns the
//!   (non-Send) PJRT runtime; clients submit jobs over a channel and
//!   receive tickets.

pub mod backend;
pub mod convergence;
pub mod driver;
pub mod job;
pub mod service;

pub use backend::{CpuBackend, EvalBackend, PjrtBackend};
pub use convergence::Convergence;
pub use driver::run_job;
pub use job::{EvalJob, JobResult, WorkSpec};
pub use service::{EvalService, ServiceTelemetry};
