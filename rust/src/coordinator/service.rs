//! The threaded evaluation service.
//!
//! A dedicated executor thread owns the backend — deliberately, because
//! the PJRT FFI types are not `Send`: the backend is constructed *inside*
//! the executor thread from a `Send` factory closure. Clients hold a
//! cheap cloneable [`EvalService`] handle and submit jobs over an mpsc
//! channel, receiving a ticket (`std::sync::mpsc::Receiver`) that resolves
//! to the [`JobResult`]. Telemetry is aggregated behind a mutex.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, Result};

use super::backend::EvalBackend;
use super::driver::run_job;
use super::job::{EvalJob, JobResult};

/// Aggregated service counters.
#[derive(Clone, Debug, Default)]
pub struct ServiceTelemetry {
    pub jobs_completed: u64,
    pub jobs_failed: u64,
    pub pairs_evaluated: u64,
    pub batches_executed: u64,
    pub busy: Duration,
}

enum Request {
    Job(EvalJob, Sender<Result<JobResult>>),
    Shutdown,
}

/// Client handle to the evaluation service.
pub struct EvalService {
    tx: Sender<Request>,
    telemetry: Arc<Mutex<ServiceTelemetry>>,
    worker: Option<JoinHandle<()>>,
}

/// A pending result.
pub struct JobTicket {
    rx: Receiver<Result<JobResult>>,
}

impl JobTicket {
    /// Block until the job completes.
    pub fn wait(self) -> Result<JobResult> {
        self.rx
            .recv()
            .map_err(|_| anyhow!("evaluation service dropped the job"))?
    }

    /// Non-blocking poll.
    pub fn try_wait(&self) -> Option<Result<JobResult>> {
        self.rx.try_recv().ok()
    }
}

impl EvalService {
    /// Start the service. `factory` runs on the executor thread and builds
    /// the backend there (PJRT types are not `Send`).
    pub fn start<F>(factory: F) -> Result<EvalService>
    where
        F: FnOnce() -> Result<Box<dyn EvalBackend>> + Send + 'static,
    {
        let (tx, rx) = channel::<Request>();
        let telemetry = Arc::new(Mutex::new(ServiceTelemetry::default()));
        let tele = telemetry.clone();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let worker = std::thread::Builder::new()
            .name("segmul-eval".into())
            .spawn(move || {
                let mut backend = match factory() {
                    Ok(b) => {
                        let _ = ready_tx.send(Ok(()));
                        b
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    match req {
                        Request::Shutdown => break,
                        Request::Job(job, reply) => {
                            let started = std::time::Instant::now();
                            let result = run_job(backend.as_mut(), &job);
                            let mut t = tele.lock().unwrap();
                            t.busy += started.elapsed();
                            match &result {
                                Ok(r) => {
                                    t.jobs_completed += 1;
                                    t.pairs_evaluated += r.stats.count;
                                    t.batches_executed += r.batches;
                                }
                                Err(_) => t.jobs_failed += 1,
                            }
                            drop(t);
                            let _ = reply.send(result);
                        }
                    }
                }
            })?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("executor thread died during startup"))??;
        Ok(EvalService { tx, telemetry, worker: Some(worker) })
    }

    /// Submit a job; returns a ticket resolving to the result.
    pub fn submit(&self, job: EvalJob) -> JobTicket {
        let (reply_tx, reply_rx) = channel();
        // If the executor is gone the ticket's recv() will error out.
        let _ = self.tx.send(Request::Job(job, reply_tx));
        JobTicket { rx: reply_rx }
    }

    /// Submit and wait (convenience).
    pub fn eval(&self, job: EvalJob) -> Result<JobResult> {
        self.submit(job).wait()
    }

    pub fn telemetry(&self) -> ServiceTelemetry {
        self.telemetry.lock().unwrap().clone()
    }

    /// Graceful shutdown (also runs on drop).
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

impl Drop for EvalService {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::CpuBackend;
    use crate::error::exhaustive::exhaustive_stats;

    fn cpu_service() -> EvalService {
        EvalService::start(|| Ok(Box::new(CpuBackend::new()) as Box<dyn EvalBackend>)).unwrap()
    }

    #[test]
    fn end_to_end_job() {
        let svc = cpu_service();
        let r = svc.eval(EvalJob::exhaustive(6, 3, true)).unwrap();
        assert!(r.stats.approx_eq(&exhaustive_stats(6, 3, true)));
        let t = svc.telemetry();
        assert_eq!(t.jobs_completed, 1);
        assert_eq!(t.pairs_evaluated, 1 << 12);
        svc.shutdown();
    }

    #[test]
    fn pipelined_submissions() {
        let svc = cpu_service();
        let tickets: Vec<_> = (1..=4u32)
            .map(|t| svc.submit(EvalJob::mc(8, t, true, 10_000, t as u64)))
            .collect();
        let mut counts = 0;
        for ticket in tickets {
            let r = ticket.wait().unwrap();
            assert_eq!(r.stats.count, 10_000);
            counts += 1;
        }
        assert_eq!(counts, 4);
        assert_eq!(svc.telemetry().jobs_completed, 4);
    }

    #[test]
    fn failed_jobs_reported() {
        let svc = cpu_service();
        let r = svc.eval(EvalJob::mc(8, 20, false, 10, 1));
        assert!(r.is_err());
        assert_eq!(svc.telemetry().jobs_failed, 1);
    }

    #[test]
    fn factory_failure_propagates() {
        let r = EvalService::start(|| Err(anyhow!("boom")));
        assert!(r.is_err());
    }

    #[test]
    fn drop_shuts_down_cleanly() {
        let svc = cpu_service();
        let _ = svc.eval(EvalJob::mc(4, 1, false, 100, 1)).unwrap();
        drop(svc); // must not hang
    }
}
