//! The threaded evaluation service.
//!
//! A pool of executor threads owns the backends — deliberately, because
//! the PJRT FFI types are not `Send`: each executor constructs its own
//! backend *inside* its thread from a shared `Fn` factory. Clients hold a
//! cheap [`EvalService`] handle and submit jobs over an mpsc channel,
//! receiving a ticket (`std::sync::mpsc::Receiver`) that resolves to the
//! [`JobResult`]. Workers pull from the shared queue as they free up
//! (the idle worker holds the queue lock only while blocked on `recv`,
//! never while evaluating), so an N-worker pool schedules N jobs
//! concurrently with no partitioning decisions up front. Telemetry is
//! aggregated behind a mutex shared by the pool.
//!
//! Per-job results are independent of which worker ran them (the chunk
//! decomposition in [`super::driver::ChunkPlan`] depends only on the job
//! and the backend batch size), so pooling changes throughput, never
//! statistics. Jobs carry a [`crate::multiplier::MultiplierSpec`], so any
//! design the worker's backend supports — not just the paper's — flows
//! through this service unchanged. For intra-job parallelism see
//! [`super::sharded`]; for a pool whose workers keep their backend across
//! jobs with intra-job sharding, see [`super::pool::WorkerPool`] (what
//! the [`crate::api::Session`] facade runs on).

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, Result};

use super::backend::EvalBackend;
use super::driver::run_job;
use super::job::{EvalJob, JobResult};

/// Aggregated service counters.
#[derive(Clone, Debug, Default)]
pub struct ServiceTelemetry {
    /// Jobs completed successfully.
    pub jobs_completed: u64,
    /// Jobs that surfaced an error.
    pub jobs_failed: u64,
    /// Operand pairs evaluated.
    pub pairs_evaluated: u64,
    /// Backend batch executions.
    pub batches_executed: u64,
    /// Cumulative busy time across workers.
    pub busy: Duration,
}

enum Request {
    Job(EvalJob, Sender<Result<JobResult>>),
    Shutdown,
}

/// Client handle to the evaluation service.
pub struct EvalService {
    tx: Sender<Request>,
    telemetry: Arc<Mutex<ServiceTelemetry>>,
    workers: Vec<JoinHandle<()>>,
}

/// A pending result.
pub struct JobTicket {
    rx: Receiver<Result<JobResult>>,
}

impl JobTicket {
    /// Block until the job completes.
    pub fn wait(self) -> Result<JobResult> {
        self.rx
            .recv()
            .map_err(|_| anyhow!("evaluation service dropped the job"))?
    }

    /// Non-blocking poll.
    pub fn try_wait(&self) -> Option<Result<JobResult>> {
        self.rx.try_recv().ok()
    }
}

impl EvalService {
    /// Start a single-executor service (the pool of one). `factory` runs
    /// on the executor thread and builds the backend there (PJRT types
    /// are not `Send`).
    pub fn start<F>(factory: F) -> Result<EvalService>
    where
        F: Fn() -> Result<Box<dyn EvalBackend>> + Send + Sync + 'static,
    {
        Self::start_pool(factory, 1)
    }

    /// Start an N-worker pool. `factory` is invoked once per worker, in
    /// that worker's thread; startup fails if any backend fails to build.
    pub fn start_pool<F>(factory: F, workers: usize) -> Result<EvalService>
    where
        F: Fn() -> Result<Box<dyn EvalBackend>> + Send + Sync + 'static,
    {
        let workers = workers.max(1);
        let (tx, rx) = channel::<Request>();
        let rx = Arc::new(Mutex::new(rx));
        let factory = Arc::new(factory);
        let telemetry = Arc::new(Mutex::new(ServiceTelemetry::default()));
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let mut handles = Vec::with_capacity(workers);
        for i in 0..workers {
            let rx = rx.clone();
            let tele = telemetry.clone();
            let factory = factory.clone();
            let ready_tx = ready_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("segmul-eval-{i}"))
                .spawn(move || {
                    let mut backend = match factory() {
                        Ok(b) => {
                            let _ = ready_tx.send(Ok(()));
                            b
                        }
                        Err(e) => {
                            let _ = ready_tx.send(Err(e));
                            return;
                        }
                    };
                    loop {
                        // Hold the queue lock only while waiting, never
                        // while evaluating.
                        let req = match rx.lock() {
                            Ok(guard) => guard.recv(),
                            Err(_) => break,
                        };
                        match req {
                            Err(_) | Ok(Request::Shutdown) => break,
                            Ok(Request::Job(job, reply)) => {
                                let started = std::time::Instant::now();
                                let result = run_job(backend.as_mut(), &job);
                                let mut t = tele.lock().unwrap();
                                t.busy += started.elapsed();
                                match &result {
                                    Ok(r) => {
                                        t.jobs_completed += 1;
                                        t.pairs_evaluated += r.stats.count;
                                        t.batches_executed += r.batches;
                                    }
                                    Err(_) => t.jobs_failed += 1,
                                }
                                drop(t);
                                let _ = reply.send(result);
                            }
                        }
                    }
                })?;
            handles.push(handle);
        }
        drop(ready_tx);
        for _ in 0..workers {
            // On failure, dropping `tx` (and the handles) unblocks the
            // already-started workers, which exit on the closed channel.
            ready_rx
                .recv()
                .map_err(|_| anyhow!("executor thread died during startup"))??;
        }
        Ok(EvalService { tx, telemetry, workers: handles })
    }

    /// Number of executor threads in the pool.
    pub fn pool_size(&self) -> usize {
        self.workers.len()
    }

    /// Submit a job; returns a ticket resolving to the result.
    pub fn submit(&self, job: EvalJob) -> JobTicket {
        let (reply_tx, reply_rx) = channel();
        // If the executors are gone the ticket's recv() will error out.
        let _ = self.tx.send(Request::Job(job, reply_tx));
        JobTicket { rx: reply_rx }
    }

    /// Submit and wait (convenience).
    pub fn eval(&self, job: EvalJob) -> Result<JobResult> {
        self.submit(job).wait()
    }

    /// Snapshot of the aggregated counters.
    pub fn telemetry(&self) -> ServiceTelemetry {
        self.telemetry.lock().unwrap().clone()
    }

    /// Graceful shutdown (also runs on drop).
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        for _ in 0..self.workers.len() {
            let _ = self.tx.send(Request::Shutdown);
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for EvalService {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::CpuBackend;
    use crate::error::exhaustive::exhaustive_stats;

    fn cpu_factory() -> impl Fn() -> Result<Box<dyn EvalBackend>> + Send + Sync + 'static {
        || Ok(Box::new(CpuBackend::new()) as Box<dyn EvalBackend>)
    }

    fn cpu_service() -> EvalService {
        EvalService::start(cpu_factory()).unwrap()
    }

    #[test]
    fn end_to_end_job() {
        let svc = cpu_service();
        let r = svc.eval(EvalJob::exhaustive(6, 3, true)).unwrap();
        assert!(r.stats.approx_eq(&exhaustive_stats(6, 3, true)));
        let t = svc.telemetry();
        assert_eq!(t.jobs_completed, 1);
        assert_eq!(t.pairs_evaluated, 1 << 12);
        svc.shutdown();
    }

    #[test]
    fn pipelined_submissions() {
        let svc = cpu_service();
        let tickets: Vec<_> = (1..=4u32)
            .map(|t| svc.submit(EvalJob::mc(8, t, true, 10_000, t as u64)))
            .collect();
        let mut counts = 0;
        for ticket in tickets {
            let r = ticket.wait().unwrap();
            assert_eq!(r.stats.count, 10_000);
            counts += 1;
        }
        assert_eq!(counts, 4);
        assert_eq!(svc.telemetry().jobs_completed, 4);
    }

    #[test]
    fn pool_processes_all_jobs() {
        let svc = EvalService::start_pool(cpu_factory(), 3).unwrap();
        assert_eq!(svc.pool_size(), 3);
        let tickets: Vec<_> = (0..12u64)
            .map(|i| svc.submit(EvalJob::mc(8, 1 + (i % 7) as u32, i % 2 == 0, 20_000, i)))
            .collect();
        for ticket in tickets {
            assert_eq!(ticket.wait().unwrap().stats.count, 20_000);
        }
        let t = svc.telemetry();
        assert_eq!(t.jobs_completed, 12);
        assert_eq!(t.pairs_evaluated, 12 * 20_000);
        svc.shutdown();
    }

    #[test]
    fn pool_results_match_single_executor() {
        // Which worker runs a job must not affect its statistics.
        let pool = EvalService::start_pool(cpu_factory(), 4).unwrap();
        let single = cpu_service();
        let jobs: Vec<_> = (1..=5u32).map(|t| EvalJob::mc(8, t, true, 50_000, 42)).collect();
        let pool_tickets: Vec<_> = jobs.iter().map(|j| pool.submit(j.clone())).collect();
        for (job, ticket) in jobs.iter().zip(pool_tickets) {
            let p = ticket.wait().unwrap();
            let s = single.eval(job.clone()).unwrap();
            assert_eq!(p.stats, s.stats, "design={}", job.design.name());
        }
    }

    #[test]
    fn failed_jobs_reported() {
        let svc = cpu_service();
        let r = svc.eval(EvalJob::mc(8, 20, false, 10, 1));
        assert!(r.is_err());
        assert_eq!(svc.telemetry().jobs_failed, 1);
    }

    #[test]
    fn factory_failure_propagates() {
        let r = EvalService::start(|| Err(anyhow!("boom")));
        assert!(r.is_err());
        let r = EvalService::start_pool(|| Err(anyhow!("boom")), 3);
        assert!(r.is_err());
    }

    #[test]
    fn drop_shuts_down_cleanly() {
        let svc = EvalService::start_pool(cpu_factory(), 2).unwrap();
        let _ = svc.eval(EvalJob::mc(4, 1, false, 100, 1)).unwrap();
        drop(svc); // must not hang
    }
}
