//! Confidence-interval-based early stopping for adaptive MC jobs.
//!
//! The monitored quantity is ER (a binomial proportion): its standard
//! error is `sqrt(p(1-p)/N)`. A job converges when the *relative* standard
//! error drops below the target — or, for error-free configurations, when
//! enough samples have shown no error to bound ER below the target with
//! the rule-of-three.
//!
//! Determinism contract: callers must evaluate [`Convergence::converged`]
//! on chunk-ordered prefixes only (after each single in-order chunk
//! merge, as the sequential driver does). The sharded runner preserves
//! exactly that schedule via `OrderedMerger::step`, which is why an
//! adaptive job stops at the same chunk — and returns bit-identical
//! stats — for any worker count.

use crate::error::metrics::ErrorStats;

/// Convergence policy.
#[derive(Clone, Copy, Debug)]
pub struct Convergence {
    /// Target relative standard error on ER (e.g. 0.01 = 1%).
    pub target_rel_stderr: f64,
    /// Never stop before this many samples.
    pub min_samples: u64,
}

impl Convergence {
    /// A policy targeting `target_rel_stderr` with the default 2^12-sample minimum.
    pub fn new(target_rel_stderr: f64) -> Self {
        Self { target_rel_stderr, min_samples: 1 << 12 }
    }

    /// Relative standard error of the ER estimate (∞ when undefined).
    pub fn rel_stderr(stats: &ErrorStats) -> f64 {
        if stats.count == 0 || stats.err_count == 0 {
            return f64::INFINITY;
        }
        let n = stats.count as f64;
        let p = stats.err_count as f64 / n;
        let se = (p * (1.0 - p) / n).sqrt();
        if p == 0.0 {
            f64::INFINITY
        } else {
            se / p
        }
    }

    /// Should the job stop?
    pub fn converged(&self, stats: &ErrorStats) -> bool {
        if stats.count < self.min_samples {
            return false;
        }
        if stats.err_count == 0 {
            // rule of three: with N error-free samples, ER < 3/N at 95%.
            // Treat "ER bounded below target_rel_stderr as absolute" as done.
            return (3.0 / stats.count as f64) < self.target_rel_stderr;
        }
        Self::rel_stderr(stats) < self.target_rel_stderr
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats_with(count: u64, errs: u64) -> ErrorStats {
        let mut s = ErrorStats::new(8);
        s.count = count;
        s.err_count = errs;
        s
    }

    #[test]
    fn more_samples_tighter_ci() {
        let a = Convergence::rel_stderr(&stats_with(1_000, 100));
        let b = Convergence::rel_stderr(&stats_with(100_000, 10_000));
        assert!(b < a);
    }

    #[test]
    fn converges_at_target() {
        let c = Convergence::new(0.02);
        // p = 0.5, N = 10^4: rel stderr = sqrt(.25/1e4)/.5 = 0.01 < 0.02
        assert!(c.converged(&stats_with(10_000, 5_000)));
        // N = 10^3: 0.0316 > 0.02
        assert!(!c.converged(&stats_with(1_000, 500)));
    }

    #[test]
    fn min_samples_respected() {
        let mut c = Convergence::new(0.5);
        c.min_samples = 1 << 20;
        assert!(!c.converged(&stats_with(10_000, 5_000)));
    }

    #[test]
    fn error_free_uses_rule_of_three() {
        let c = Convergence::new(0.0001);
        assert!(!c.converged(&stats_with(10_000, 0))); // 3/1e4 = 3e-4 > 1e-4
        assert!(c.converged(&stats_with(100_000, 0))); // 3/1e5 = 3e-5 < 1e-4
    }

    #[test]
    fn monotone_in_samples_at_fixed_rate() {
        // Convergence is monotone: once converged at rate p, more samples
        // at the same p keep it converged.
        let c = Convergence::new(0.05);
        let mut prev = false;
        for k in 1..=8u32 {
            let n = 1u64 << (10 + k);
            let now = c.converged(&stats_with(n, n / 10));
            assert!(!prev || now, "convergence regressed at n={n}");
            prev = now;
        }
    }
}
