//! Evaluation backends.
//!
//! A backend turns an operand batch into an [`ErrorStats`]. The CPU backend
//! runs the word-level model; the PJRT backend executes the AOT-compiled
//! stats module (one `execute` per batch, O(1) host transfer). Both produce
//! identical integer statistics for identical inputs — property-tested in
//! `coordinator_integration`.

use std::path::Path;

use anyhow::Result;

use crate::error::metrics::ErrorStats;
use crate::error::stream::BatchAccumulator;
use crate::multiplier::SegmentedSeqMul;
use crate::runtime::Runtime;

/// A batch evaluator for the segmented sequential multiplier.
pub trait EvalBackend {
    fn name(&self) -> &'static str;
    /// Preferred operand-batch size.
    fn max_batch(&self) -> usize;
    /// Whether this backend can evaluate bit-width `n`.
    fn supports(&self, n: u32) -> bool;
    /// Evaluate one batch (`a.len() == b.len()`, any length ≤ max_batch).
    fn eval_batch(&mut self, n: u32, t: u32, fix: bool, a: &[u64], b: &[u64]) -> Result<ErrorStats>;
}

/// Pure-Rust word-level backend (always available, any n ≤ 32). A thin
/// wrapper over the batched streaming engine: each call runs the same
/// monomorphized kernels + block-resident `BatchAccumulator` the
/// standalone evaluators use — no per-pair dispatch anywhere.
pub struct CpuBackend {
    batch: usize,
}

impl CpuBackend {
    pub fn new() -> Self {
        Self { batch: 1 << 16 }
    }
}

impl Default for CpuBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl EvalBackend for CpuBackend {
    fn name(&self) -> &'static str {
        "cpu"
    }

    fn max_batch(&self) -> usize {
        self.batch
    }

    fn supports(&self, n: u32) -> bool {
        (1..=32).contains(&n)
    }

    fn eval_batch(&mut self, n: u32, t: u32, fix: bool, a: &[u64], b: &[u64]) -> Result<ErrorStats> {
        anyhow::ensure!(a.len() == b.len());
        anyhow::ensure!((1..=32).contains(&n), "n={n} out of range");
        anyhow::ensure!(t < n, "t={t} out of range for n={n}");
        let m = SegmentedSeqMul::new(n, t, fix);
        let mut acc = BatchAccumulator::new(&m);
        acc.eval_pairs(a, b);
        Ok(acc.finish())
    }
}

/// PJRT backend over the AOT artifacts. Short batches are padded with
/// `(0, 0)` pairs — exact products that perturb only the sample count,
/// which is corrected after execution.
pub struct PjrtBackend {
    runtime: Runtime,
}

impl PjrtBackend {
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        Ok(Self { runtime: Runtime::load(artifacts_dir)? })
    }

    pub fn from_runtime(runtime: Runtime) -> Self {
        Self { runtime }
    }

    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }
}

impl EvalBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn max_batch(&self) -> usize {
        self.runtime.batch()
    }

    fn supports(&self, n: u32) -> bool {
        self.runtime.has(n, crate::runtime::ModuleKind::Stats)
    }

    fn eval_batch(&mut self, n: u32, t: u32, fix: bool, a: &[u64], b: &[u64]) -> Result<ErrorStats> {
        anyhow::ensure!(a.len() == b.len());
        anyhow::ensure!(a.len() <= self.runtime.batch(), "batch too large");
        let pad = self.runtime.batch() - a.len();
        let v = if pad == 0 {
            self.runtime.exec_stats(n, a, b, t as u64, fix)?
        } else {
            let mut ap = a.to_vec();
            let mut bp = b.to_vec();
            ap.resize(self.runtime.batch(), 0);
            bp.resize(self.runtime.batch(), 0);
            self.runtime.exec_stats(n, &ap, &bp, t as u64, fix)?
        };
        let mut stats = ErrorStats::from_f64_vec(n, &v)?;
        // (0,0) pads are exact: only `count` needs correcting.
        stats.count -= pad as u64;
        Ok(stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::multiplier::wordlevel::approx_seq_mul;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn cpu_backend_matches_direct_record() {
        let mut be = CpuBackend::new();
        let mut rng = Xoshiro256::seed_from_u64(1);
        let a: Vec<u64> = (0..500).map(|_| rng.next_bits(8)).collect();
        let b: Vec<u64> = (0..500).map(|_| rng.next_bits(8)).collect();
        let got = be.eval_batch(8, 4, true, &a, &b).unwrap();
        let mut want = ErrorStats::new(8);
        for (&x, &y) in a.iter().zip(&b) {
            want.record(x * y, approx_seq_mul(x, y, 8, 4, true));
        }
        assert_eq!(got, want);
    }

    #[test]
    fn cpu_backend_supports_range() {
        let be = CpuBackend::new();
        assert!(be.supports(1) && be.supports(32));
        assert!(!be.supports(0) && !be.supports(33));
    }
}
