//! Evaluation backends.
//!
//! A backend turns an operand batch into an [`ErrorStats`]. The CPU backend
//! runs the word-level model; the PJRT backend executes lowered modules —
//! the AOT-compiled stats modules of the segmented family (one `execute`
//! per batch, O(1) host transfer) and the design-lowered modules of every
//! registry design (`segmul lower`). Both backends produce identical
//! statistics for identical inputs — the design-lowered path bit-exactly
//! (`tests/pjrt_lowered_differential.rs`), the f64 stats-vector path up to
//! integer equality (`coordinator_integration`).

use std::collections::hash_map::Entry;
use std::collections::{BTreeMap, HashMap};
use std::path::Path;

use anyhow::{anyhow, Result};

use crate::error::metrics::ErrorStats;
use crate::error::stream::BatchAccumulator;
use crate::multiplier::{BatchMultiplier, DispatchClass, MultiplierSpec, SegmentedSeqMul};
use crate::runtime::Runtime;

/// A batch evaluator. The segmented fast path ([`Self::eval_batch`]) is
/// what the legacy AOT stats modules lower; [`Self::eval_design`]
/// generalizes to any [`MultiplierSpec`] — by default only the segmented
/// family (plus the accurate design, which is its `t = 0` point), with
/// the CPU backend overriding it to evaluate every implemented design
/// and the PJRT backend overriding it to dispatch any design that has a
/// `segmul lower` module.
pub trait EvalBackend {
    fn name(&self) -> &'static str;
    /// Preferred operand-batch size.
    fn max_batch(&self) -> usize;
    /// Whether this backend can evaluate bit-width `n`.
    fn supports(&self, n: u32) -> bool;
    /// Evaluate one batch of the paper's segmented design
    /// (`a.len() == b.len()`, any length ≤ max_batch).
    fn eval_batch(&mut self, n: u32, t: u32, fix: bool, a: &[u64], b: &[u64]) -> Result<ErrorStats>;

    /// Whether this backend can evaluate `design`. The default covers
    /// exactly what the default [`Self::eval_design`] can run.
    fn supports_design(&self, design: &MultiplierSpec) -> bool {
        design.has_segmented_lowering() && self.supports(design.n())
    }

    /// Evaluate one batch of an arbitrary design. Defaults to routing the
    /// segmented family through [`Self::eval_batch`] (the accurate design
    /// is segmented `t = 0`) and rejecting everything else.
    fn eval_design(&mut self, design: &MultiplierSpec, a: &[u64], b: &[u64]) -> Result<ErrorStats> {
        match *design {
            MultiplierSpec::Segmented { n, t, fix } => self.eval_batch(n, t, fix, a, b),
            MultiplierSpec::Accurate { n } => self.eval_batch(n, 0, false, a, b),
            ref other => Err(anyhow!(
                "backend {} does not support design {}",
                self.name(),
                other.name()
            )),
        }
    }

    /// Which kernel tier each design evaluated so far ran on, as
    /// `(design name, class)` pairs. The CPU backend reports
    /// [`DispatchClass::Batched`] per design, the PJRT backend
    /// [`DispatchClass::Pjrt`] per lowered dispatch — so sweeps can prove
    /// both that nothing silently regressed to per-pair dispatch and that
    /// an accelerator sweep never fell back to the CPU tier
    /// (`segmul sweep --require-pjrt`).
    fn kernel_dispatch(&self) -> Vec<(String, DispatchClass)> {
        Vec::new()
    }
}

/// Pure-Rust word-level backend (always available, any n ≤ 32). A thin
/// wrapper over the batched streaming engine: each call runs the same
/// monomorphized kernels + block-resident `BatchAccumulator` the
/// standalone evaluators use — no per-pair dispatch anywhere. The only
/// backend that evaluates **every** [`MultiplierSpec`]: non-segmented
/// designs run through evaluators built once per spec and cached for the
/// backend's lifetime (a netlist build amortizes across all its chunks).
pub struct CpuBackend {
    batch: usize,
    /// Built evaluators for non-segmented designs, keyed by spec.
    designs: HashMap<MultiplierSpec, Box<dyn BatchMultiplier>>,
    /// Kernel tier each evaluated design ran on, keyed by design name
    /// (BTreeMap: deterministic report order).
    dispatch: BTreeMap<String, DispatchClass>,
}

impl CpuBackend {
    /// A CPU backend with the default 2^16 batch size.
    pub fn new() -> Self {
        Self { batch: 1 << 16, designs: HashMap::new(), dispatch: BTreeMap::new() }
    }
}

impl Default for CpuBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl EvalBackend for CpuBackend {
    fn name(&self) -> &'static str {
        "cpu"
    }

    fn max_batch(&self) -> usize {
        self.batch
    }

    fn supports(&self, n: u32) -> bool {
        (1..=32).contains(&n)
    }

    fn eval_batch(&mut self, n: u32, t: u32, fix: bool, a: &[u64], b: &[u64]) -> Result<ErrorStats> {
        anyhow::ensure!(a.len() == b.len());
        anyhow::ensure!((1..=32).contains(&n), "n={n} out of range");
        anyhow::ensure!(t < n, "t={t} out of range for n={n}");
        let m = SegmentedSeqMul::new(n, t, fix);
        self.dispatch
            .entry(BatchMultiplier::name(&m))
            .or_insert_with(|| BatchMultiplier::dispatch_class(&m));
        let mut acc = BatchAccumulator::new(&m);
        acc.eval_pairs(a, b);
        Ok(acc.finish())
    }

    fn supports_design(&self, design: &MultiplierSpec) -> bool {
        design.validate().is_ok()
    }

    fn eval_design(&mut self, design: &MultiplierSpec, a: &[u64], b: &[u64]) -> Result<ErrorStats> {
        match *design {
            // The segmented fast path stays byte-for-byte the old route.
            MultiplierSpec::Segmented { n, t, fix } => self.eval_batch(n, t, fix, a, b),
            ref other => {
                anyhow::ensure!(a.len() == b.len());
                let m = match self.designs.entry(*other) {
                    Entry::Occupied(e) => e.into_mut(),
                    Entry::Vacant(v) => v.insert(other.build_batch()?),
                };
                self.dispatch.entry(other.name()).or_insert_with(|| m.dispatch_class());
                let mut acc = BatchAccumulator::new(m.as_ref());
                acc.eval_pairs(a, b);
                Ok(acc.finish())
            }
        }
    }

    fn kernel_dispatch(&self) -> Vec<(String, DispatchClass)> {
        self.dispatch.iter().map(|(k, v)| (k.clone(), *v)).collect()
    }
}

/// PJRT backend over the AOT artifacts: the legacy stats modules of the
/// segmented family (`make artifacts`) plus the design-lowered modules of
/// every registry design (`segmul lower`), so `--designs all` sweeps run
/// fully on the accelerator backend. Short batches are padded with
/// `(0, 0)` pairs — exact products that never reach the statistics (the
/// lowered path truncates them; the stats path corrects `count`).
///
/// Every design evaluated here reports [`DispatchClass::Pjrt`] in the
/// kernel-dispatch telemetry, which is what the sweep audit
/// (`segmul sweep --require-pjrt`) gates on.
pub struct PjrtBackend {
    runtime: Runtime,
    /// Kernel tier per evaluated design (BTreeMap: deterministic order).
    dispatch: BTreeMap<String, DispatchClass>,
}

impl PjrtBackend {
    /// Load the artifact manifest under `artifacts_dir` and wrap it.
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        Ok(Self::from_runtime(Runtime::load(artifacts_dir)?))
    }

    /// Wrap an already-loaded runtime.
    pub fn from_runtime(runtime: Runtime) -> Self {
        Self { runtime, dispatch: BTreeMap::new() }
    }

    /// The underlying PJRT runtime.
    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }

    /// Execute `design` through its lowered module and fold the products
    /// into [`ErrorStats`] host-side — bit-identical accumulation to the
    /// CPU backend over the same operand slice (`record_batch` in input
    /// order; the lowered integer sums are exact, never f64-rounded).
    fn eval_lowered(&mut self, design: &MultiplierSpec, a: &[u64], b: &[u64]) -> Result<ErrorStats> {
        anyhow::ensure!(a.len() == b.len());
        anyhow::ensure!(a.len() <= self.runtime.batch(), "batch too large");
        let batch = self.runtime.batch();
        let phat = if a.len() == batch {
            self.runtime.exec_lowered(design, a, b)?
        } else {
            // Pad to the static batch shape; pad products are dropped
            // before any statistic sees them.
            let mut ap = a.to_vec();
            let mut bp = b.to_vec();
            ap.resize(batch, 0);
            bp.resize(batch, 0);
            self.runtime.exec_lowered(design, &ap, &bp)?
        };
        let mut prod = vec![0u64; a.len()];
        crate::multiplier::exact_mul_batch(a, b, &mut prod);
        let mut stats = ErrorStats::new(design.n());
        stats.record_batch(&prod, &phat[..a.len()]);
        Ok(stats)
    }
}

impl EvalBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn max_batch(&self) -> usize {
        self.runtime.batch()
    }

    fn supports(&self, n: u32) -> bool {
        self.runtime.supports_bitwidth(n)
    }

    fn eval_batch(&mut self, n: u32, t: u32, fix: bool, a: &[u64], b: &[u64]) -> Result<ErrorStats> {
        anyhow::ensure!(a.len() == b.len());
        anyhow::ensure!(a.len() <= self.runtime.batch(), "batch too large");
        if !self.runtime.has(n, crate::runtime::ModuleKind::Stats) {
            // No legacy stats module: serve the segmented point from its
            // design-lowered module when one exists.
            let spec = MultiplierSpec::Segmented { n, t, fix };
            if self.runtime.has_lowered(&spec) {
                let stats = self.eval_lowered(&spec, a, b)?;
                self.dispatch.entry(spec.name()).or_insert(DispatchClass::Pjrt);
                return Ok(stats);
            }
        }
        let pad = self.runtime.batch() - a.len();
        let v = if pad == 0 {
            self.runtime.exec_stats(n, a, b, t as u64, fix)?
        } else {
            let mut ap = a.to_vec();
            let mut bp = b.to_vec();
            ap.resize(self.runtime.batch(), 0);
            bp.resize(self.runtime.batch(), 0);
            self.runtime.exec_stats(n, &ap, &bp, t as u64, fix)?
        };
        self.dispatch
            .entry(MultiplierSpec::Segmented { n, t, fix }.name())
            .or_insert(DispatchClass::Pjrt);
        let mut stats = ErrorStats::from_f64_vec(n, &v)?;
        // (0,0) pads are exact: only `count` needs correcting.
        stats.count -= pad as u64;
        Ok(stats)
    }

    fn supports_design(&self, design: &MultiplierSpec) -> bool {
        design.validate().is_ok()
            && (self.runtime.has_lowered(design)
                || (design.has_segmented_lowering()
                    && self.runtime.has(design.n(), crate::runtime::ModuleKind::Stats)))
    }

    fn eval_design(&mut self, design: &MultiplierSpec, a: &[u64], b: &[u64]) -> Result<ErrorStats> {
        if self.runtime.has_lowered(design) {
            let stats = self.eval_lowered(design, a, b)?;
            self.dispatch.entry(design.name()).or_insert(DispatchClass::Pjrt);
            return Ok(stats);
        }
        match *design {
            MultiplierSpec::Segmented { n, t, fix } => self.eval_batch(n, t, fix, a, b),
            MultiplierSpec::Accurate { n } => self.eval_batch(n, 0, false, a, b),
            ref other => Err(anyhow!(
                "backend pjrt has no lowered module for design {} (run `segmul lower`)",
                other.name()
            )),
        }
    }

    fn kernel_dispatch(&self) -> Vec<(String, DispatchClass)> {
        self.dispatch.iter().map(|(k, v)| (k.clone(), *v)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::multiplier::wordlevel::approx_seq_mul;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn cpu_backend_matches_direct_record() {
        let mut be = CpuBackend::new();
        let mut rng = Xoshiro256::seed_from_u64(1);
        let a: Vec<u64> = (0..500).map(|_| rng.next_bits(8)).collect();
        let b: Vec<u64> = (0..500).map(|_| rng.next_bits(8)).collect();
        let got = be.eval_batch(8, 4, true, &a, &b).unwrap();
        let mut want = ErrorStats::new(8);
        for (&x, &y) in a.iter().zip(&b) {
            want.record(x * y, approx_seq_mul(x, y, 8, 4, true));
        }
        assert_eq!(got, want);
    }

    #[test]
    fn cpu_backend_supports_range() {
        let be = CpuBackend::new();
        assert!(be.supports(1) && be.supports(32));
        assert!(!be.supports(0) && !be.supports(33));
    }

    #[test]
    fn cpu_backend_evaluates_every_design() {
        let mut be = CpuBackend::new();
        let mut rng = Xoshiro256::seed_from_u64(7);
        let a: Vec<u64> = (0..300).map(|_| rng.next_bits(8)).collect();
        let b: Vec<u64> = (0..300).map(|_| rng.next_bits(8)).collect();
        for spec in MultiplierSpec::registry_examples(8) {
            assert!(be.supports_design(&spec), "{}", spec.name());
            let got = be.eval_design(&spec, &a, &b).unwrap();
            assert_eq!(got.count, 300, "{}", spec.name());
            // Reference: drive the same evaluator directly.
            let m = spec.build_batch().unwrap();
            let mut acc = BatchAccumulator::new(m.as_ref());
            acc.eval_pairs(&a, &b);
            assert_eq!(got, acc.finish(), "{}", spec.name());
        }
        // Segmented routing through eval_design == eval_batch.
        let spec = MultiplierSpec::Segmented { n: 8, t: 4, fix: true };
        let via_design = be.eval_design(&spec, &a, &b).unwrap();
        let via_batch = be.eval_batch(8, 4, true, &a, &b).unwrap();
        assert_eq!(via_design, via_batch);
    }

    #[test]
    fn cpu_backend_reports_batch_kernel_dispatch_for_every_design() {
        let mut be = CpuBackend::new();
        assert!(be.kernel_dispatch().is_empty(), "nothing evaluated yet");
        let mut rng = Xoshiro256::seed_from_u64(11);
        let a: Vec<u64> = (0..100).map(|_| rng.next_bits(8)).collect();
        let b: Vec<u64> = (0..100).map(|_| rng.next_bits(8)).collect();
        for spec in MultiplierSpec::registry_examples(8) {
            be.eval_design(&spec, &a, &b).unwrap();
        }
        let log = be.kernel_dispatch();
        assert_eq!(log.len(), MultiplierSpec::registry_examples(8).len());
        for (name, class) in &log {
            assert_eq!(*class, DispatchClass::Batched, "{name} fell back to per-pair dispatch");
        }
        // Repeat evaluations don't duplicate entries.
        be.eval_design(&MultiplierSpec::Mitchell { n: 8 }, &a, &b).unwrap();
        assert_eq!(be.kernel_dispatch().len(), log.len());
    }

    #[test]
    fn pjrt_backend_dispatches_every_design_through_lowered_modules() {
        let dir = std::env::temp_dir().join(format!("segmul_pjrt_backend_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let specs = MultiplierSpec::registry_examples(8);
        crate::runtime::emit_artifacts(&dir, &specs, 512).unwrap();
        let mut pjrt = PjrtBackend::load(&dir).unwrap();
        let mut cpu = CpuBackend::new();
        assert_eq!(pjrt.max_batch(), 512);
        assert!(pjrt.supports(8) && !pjrt.supports(16));
        let mut rng = Xoshiro256::seed_from_u64(21);
        // Ragged length: exercises the pad-and-truncate path.
        let a: Vec<u64> = (0..300).map(|_| rng.next_bits(8)).collect();
        let b: Vec<u64> = (0..300).map(|_| rng.next_bits(8)).collect();
        for spec in &specs {
            assert!(pjrt.supports_design(spec), "{}", spec.name());
            let sp = pjrt.eval_design(spec, &a, &b).unwrap();
            let sc = cpu.eval_design(spec, &a, &b).unwrap();
            // Bit-exact, f64 fields and approx_sums flag included.
            assert_eq!(sp, sc, "{}", spec.name());
            assert_eq!(sp.count, 300);
        }
        // The segmented fast path routes through the lowered module when
        // no legacy stats module exists.
        let via_batch = pjrt.eval_batch(8, 4, true, &a, &b).unwrap();
        let via_cpu = cpu.eval_batch(8, 4, true, &a, &b).unwrap();
        assert_eq!(via_batch, via_cpu);
        // Every dispatch is audited as the pjrt class.
        let log = pjrt.kernel_dispatch();
        assert_eq!(log.len(), specs.len());
        for (name, class) in &log {
            assert_eq!(*class, DispatchClass::Pjrt, "{name}");
        }
        // Unlowered designs carry the `segmul lower` hint.
        let e = pjrt
            .eval_design(&MultiplierSpec::Mitchell { n: 16 }, &a, &b)
            .unwrap_err()
            .to_string();
        assert!(e.contains("segmul lower"), "{e}");
        assert!(!pjrt.supports_design(&MultiplierSpec::Mitchell { n: 16 }));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn default_eval_design_rejects_non_segmented() {
        // A backend relying on the trait defaults (like the PJRT one for
        // unsupported designs) accepts segmented + accurate, rejects the
        // rest with a typed-out message.
        struct SegOnly;
        impl EvalBackend for SegOnly {
            fn name(&self) -> &'static str {
                "segonly"
            }
            fn max_batch(&self) -> usize {
                16
            }
            fn supports(&self, n: u32) -> bool {
                (1..=32).contains(&n)
            }
            fn eval_batch(
                &mut self,
                n: u32,
                t: u32,
                fix: bool,
                a: &[u64],
                b: &[u64],
            ) -> Result<ErrorStats> {
                CpuBackend::new().eval_batch(n, t, fix, a, b)
            }
        }
        let mut be = SegOnly;
        assert!(be.supports_design(&MultiplierSpec::Segmented { n: 8, t: 2, fix: false }));
        assert!(be.supports_design(&MultiplierSpec::Accurate { n: 8 }));
        assert!(!be.supports_design(&MultiplierSpec::Mitchell { n: 8 }));
        let a = [3u64, 5];
        let b = [7u64, 9];
        // Accurate routes through the exact t=0 segmented path.
        let s = be.eval_design(&MultiplierSpec::Accurate { n: 8 }, &a, &b).unwrap();
        assert_eq!(s.err_count, 0);
        let err = be
            .eval_design(&MultiplierSpec::Mitchell { n: 8 }, &a, &b)
            .unwrap_err()
            .to_string();
        assert!(err.contains("mitchell"), "{err}");
    }
}
