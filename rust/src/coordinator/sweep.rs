//! Design-space sweep orchestration: the full paper grid — and the
//! cross-design comparative grids — cached and sharded.
//!
//! A sweep enumerates every design point of the configured space: for
//! each bit-width, the configured [`DesignSet`] (the paper's
//! `(n, t, fix)` grid, the accurate reference, the related-work
//! baselines, bit-level / netlist spot checks, or all of them) under the
//! configured workload. Every point is evaluated through the persistent
//! [`WorkerPool`] — worker threads hold their backend across all grid
//! points, and the chunk-ordered merge keeps per-config results
//! bit-identical for any worker count. A result cache keyed by
//! [`JobKey`] (canonical design + workload + seed/sample budget) dedups
//! repeated configs across the sweep: the `t = 0` accurate points
//! collapse across fix modes *and* onto the accurate-design baseline,
//! and re-running a grid against a warm runner costs nothing.

use std::collections::HashMap;

use anyhow::Result;

use crate::config::Config;
use crate::multiplier::DesignSet;

use super::backend::EvalBackend;
use super::job::{EvalJob, JobKey, JobResult, WorkSpec};
use super::pool::WorkerPool;
use super::sharded::ChunkEvent;

/// The sweep grid: which design points to evaluate and under which
/// workload. The paper set covers split points `t ∈ 0..n` (0 = accurate)
/// and both fix-to-1 modes, matching the paper's axes; other sets add
/// the comparative designs of Fig. 2.
#[derive(Clone, Debug)]
pub struct SweepGrid {
    /// Operand bit-widths (paper grid: 4, 8, 16, 32).
    pub bitwidths: Vec<u32>,
    /// Design family swept per bit-width.
    pub designs: DesignSet,
    /// Evaluate exhaustively for `n <=` this (capped at 16), MC above.
    pub exhaustive_max_n: u32,
    /// Force Monte-Carlo even below the exhaustive threshold.
    pub force_mc: bool,
    /// MC sample budget per config.
    pub mc_samples: u64,
    /// Base RNG seed shared by every MC config (determinism contract).
    pub seed: u64,
}

impl SweepGrid {
    /// The configured grid from the shared [`Config`] (designs come from
    /// `[sweep] designs`, default the paper set).
    pub fn from_config(cfg: &Config) -> Result<Self, crate::error::SegmulError> {
        Ok(SweepGrid {
            bitwidths: cfg.sweep_bitwidths.clone(),
            designs: DesignSet::parse(&cfg.sweep_designs)?,
            exhaustive_max_n: cfg.exhaustive_max_n,
            force_mc: false,
            mc_samples: cfg.mc_samples,
            seed: cfg.seed,
        })
    }

    /// A single-bit-width slice of the grid.
    pub fn single(n: u32, cfg: &Config) -> Result<Self, crate::error::SegmulError> {
        Ok(SweepGrid { bitwidths: vec![n], ..Self::from_config(cfg)? })
    }

    /// Workload for one bit-width.
    fn spec(&self, n: u32) -> WorkSpec {
        if !self.force_mc && n <= self.exhaustive_max_n.min(16) {
            WorkSpec::Exhaustive
        } else {
            WorkSpec::MonteCarlo { samples: self.mc_samples, seed: self.seed }
        }
    }

    /// Materialize the jobs, in deterministic grid order: for each
    /// bit-width, every design point of the configured set (the paper
    /// set keeps the legacy order: every split point, both modes).
    pub fn jobs(&self) -> Vec<EvalJob> {
        let mut out = Vec::new();
        for &n in &self.bitwidths {
            for design in self.designs.specs(n) {
                out.push(EvalJob { design, spec: self.spec(n) });
            }
        }
        out
    }
}

/// One evaluated (or cache-served) grid point.
#[derive(Clone, Debug)]
pub struct SweepOutcome {
    /// The job as requested by the grid (cache canonicalization may have
    /// served it from an equivalent config's entry).
    pub job: EvalJob,
    pub result: JobResult,
    pub cached: bool,
}

/// Sweep executor: the persistent shard pool + the result cache.
///
/// Workers are spawned once per runner and hold their backend across
/// every grid point (replacing the old per-job backend construction of
/// `run_job_sharded`). The cache is sound because one runner holds one
/// backend factory for its whole lifetime: [`JobKey`] identity only
/// implies identical stats for a fixed backend batch size (see its docs).
pub struct SweepRunner {
    pool: WorkerPool,
    cache_enabled: bool,
    cache: HashMap<JobKey, JobResult>,
    /// Jobs served from the cache (no evaluation).
    pub cache_hits: u64,
    /// Jobs actually evaluated.
    pub jobs_evaluated: u64,
}

impl SweepRunner {
    /// Spawn the persistent pool (`workers` threads; `factory` runs once
    /// in each worker's thread).
    pub fn new<F>(factory: F, workers: usize) -> Result<Self>
    where
        F: Fn() -> Result<Box<dyn EvalBackend>> + Send + Sync + 'static,
    {
        Ok(SweepRunner {
            pool: WorkerPool::start(factory, workers)?,
            cache_enabled: true,
            cache: HashMap::new(),
            cache_hits: 0,
            jobs_evaluated: 0,
        })
    }

    pub fn workers(&self) -> usize {
        self.pool.pool_size()
    }

    /// The persistent pool backing this runner.
    pub fn pool(&self) -> &WorkerPool {
        &self.pool
    }

    /// Disable (or re-enable) the result cache — every job re-evaluates.
    pub fn set_cache_enabled(&mut self, enabled: bool) {
        self.cache_enabled = enabled;
    }

    /// Evaluate one job, consulting the cache first.
    pub fn run(&mut self, job: &EvalJob) -> Result<SweepOutcome> {
        self.run_observed(job, &mut |_| {})
    }

    /// [`Self::run`], streaming in-order chunk merges to `observer`
    /// (cache hits complete without chunk events).
    pub fn run_observed(
        &mut self,
        job: &EvalJob,
        observer: &mut dyn FnMut(ChunkEvent),
    ) -> Result<SweepOutcome> {
        let key = job.key();
        if self.cache_enabled {
            if let Some(hit) = self.cache.get(&key) {
                self.cache_hits += 1;
                // The entry may have been evaluated under an equivalent
                // design (canonicalization); report the requested one.
                let mut result = hit.clone();
                result.job = job.clone();
                return Ok(SweepOutcome { job: job.clone(), result, cached: true });
            }
        }
        let result = self.pool.run_job_observed(job, observer)?;
        self.jobs_evaluated += 1;
        if self.cache_enabled {
            self.cache.insert(key, result.clone());
        }
        Ok(SweepOutcome { job: job.clone(), result, cached: false })
    }

    /// Run a whole grid in order, streaming progress through `progress`
    /// (called once per completed point with `(index, total, outcome)`).
    pub fn run_grid(
        &mut self,
        grid: &SweepGrid,
        mut progress: impl FnMut(usize, usize, &SweepOutcome),
    ) -> Result<Vec<SweepOutcome>> {
        let jobs = grid.jobs();
        let total = jobs.len();
        let mut out = Vec::with_capacity(total);
        for (i, job) in jobs.iter().enumerate() {
            let outcome = self.run(job)?;
            progress(i, total, &outcome);
            out.push(outcome);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use std::sync::atomic::{AtomicUsize, Ordering};

    use super::*;
    use crate::coordinator::backend::CpuBackend;
    use crate::multiplier::MultiplierSpec;

    fn cpu_factory() -> impl Fn() -> Result<Box<dyn EvalBackend>> + Send + Sync + 'static {
        || Ok(Box::new(CpuBackend::new()) as Box<dyn EvalBackend>)
    }

    fn tiny_grid() -> SweepGrid {
        SweepGrid {
            bitwidths: vec![4, 6],
            designs: DesignSet::Paper,
            exhaustive_max_n: 6,
            force_mc: false,
            mc_samples: 10_000,
            seed: 3,
        }
    }

    #[test]
    fn grid_enumerates_all_points() {
        let jobs = tiny_grid().jobs();
        // (4 + 6 split points) x 2 modes.
        assert_eq!(jobs.len(), (4 + 6) * 2);
        assert!(jobs.iter().all(|j| matches!(j.spec, WorkSpec::Exhaustive)));
        let mc = SweepGrid { force_mc: true, ..tiny_grid() };
        assert!(mc.jobs().iter().all(|j| matches!(j.spec, WorkSpec::MonteCarlo { .. })));
    }

    #[test]
    fn cross_design_grid_enumerates_every_family() {
        let grid = SweepGrid { designs: DesignSet::All, bitwidths: vec![4], ..tiny_grid() };
        let jobs = grid.jobs();
        // paper (8) + accurate (1) + baselines (5: n=4 is a power of two)
        // + oracle (1) + netlist (1).
        assert_eq!(jobs.len(), 16);
        assert!(jobs.iter().any(|j| matches!(j.design, MultiplierSpec::Mitchell { .. })));
        assert!(jobs.iter().any(|j| matches!(j.design, MultiplierSpec::Netlist { .. })));
    }

    #[test]
    fn cache_dedups_t0_modes_and_repeats() {
        let grid = tiny_grid();
        let mut runner = SweepRunner::new(cpu_factory(), 2).unwrap();
        let outcomes = runner.run_grid(&grid, |_, _, _| {}).unwrap();
        assert_eq!(outcomes.len(), 20);
        // Each bit-width's (t=0, fix=true) point is served from the
        // (t=0, fix=false) entry.
        assert_eq!(runner.cache_hits, 2);
        assert_eq!(runner.jobs_evaluated, 18);
        // Re-running the same grid is fully cached.
        let again = runner.run_grid(&grid, |_, _, _| {}).unwrap();
        assert_eq!(runner.jobs_evaluated, 18);
        assert_eq!(runner.cache_hits, 2 + 20);
        assert!(again.iter().all(|o| o.cached));
        // Cached results are the same statistics objects.
        for (a, b) in outcomes.iter().zip(&again) {
            assert_eq!(a.result.stats, b.result.stats);
        }
    }

    #[test]
    fn cache_dedups_accurate_design_against_t0_points() {
        // Cross-design canonicalization: the accurate baseline shares the
        // paper grid's t=0 entry.
        let mut runner = SweepRunner::new(cpu_factory(), 1).unwrap();
        let t0 = runner.run(&EvalJob::exhaustive(6, 0, true)).unwrap();
        assert!(!t0.cached);
        let accurate = runner
            .run(&EvalJob::new(MultiplierSpec::Accurate { n: 6 }, WorkSpec::Exhaustive))
            .unwrap();
        assert!(accurate.cached, "accurate must be served from the t=0 entry");
        assert_eq!(accurate.result.stats, t0.result.stats);
        assert_eq!(runner.jobs_evaluated, 1);
    }

    #[test]
    fn cache_hits_do_not_touch_the_backend() {
        use std::sync::Arc;
        let evals = Arc::new(AtomicUsize::new(0));
        struct Counting {
            inner: CpuBackend,
            evals: Arc<AtomicUsize>,
        }
        impl EvalBackend for Counting {
            fn name(&self) -> &'static str {
                "counting"
            }
            fn max_batch(&self) -> usize {
                self.inner.max_batch()
            }
            fn supports(&self, n: u32) -> bool {
                self.inner.supports(n)
            }
            fn eval_batch(
                &mut self,
                n: u32,
                t: u32,
                fix: bool,
                a: &[u64],
                b: &[u64],
            ) -> Result<crate::error::metrics::ErrorStats> {
                self.evals.fetch_add(1, Ordering::Relaxed);
                self.inner.eval_batch(n, t, fix, a, b)
            }
        }
        let counter = evals.clone();
        let factory = move || {
            Ok(Box::new(Counting { inner: CpuBackend::new(), evals: counter.clone() })
                as Box<dyn EvalBackend>)
        };
        let mut runner = SweepRunner::new(factory, 1).unwrap();
        let job = EvalJob::mc(8, 4, true, 50_000, 1);
        let first = runner.run(&job).unwrap();
        let after_first = evals.load(Ordering::Relaxed);
        assert!(!first.cached && after_first > 0);
        let second = runner.run(&job).unwrap();
        assert!(second.cached);
        assert_eq!(evals.load(Ordering::Relaxed), after_first, "cache hit re-evaluated");
        assert_eq!(first.result.stats, second.result.stats);
    }

    #[test]
    fn cache_can_be_disabled() {
        let mut runner = SweepRunner::new(cpu_factory(), 1).unwrap();
        runner.set_cache_enabled(false);
        let job = EvalJob::mc(8, 4, true, 20_000, 1);
        let a = runner.run(&job).unwrap();
        let b = runner.run(&job).unwrap();
        assert!(!a.cached && !b.cached);
        assert_eq!(runner.jobs_evaluated, 2);
        assert_eq!(runner.cache_hits, 0);
        assert_eq!(a.result.stats, b.result.stats);
    }

    #[test]
    fn grid_results_deterministic_across_worker_counts() {
        // > 2 chunks of 2^16 per config so the stealing cursor interleaves.
        let grid = SweepGrid { force_mc: true, mc_samples: 150_000, ..tiny_grid() };
        let run = |workers| {
            let mut r = SweepRunner::new(cpu_factory(), workers).unwrap();
            r.run_grid(&grid, |_, _, _| {}).unwrap()
        };
        let w1 = run(1);
        let w3 = run(3);
        for (a, b) in w1.iter().zip(&w3) {
            assert_eq!(
                a.result.stats,
                b.result.stats,
                "design={}",
                a.job.design.name()
            );
        }
    }

    #[test]
    fn runner_backends_persist_across_grid_points() {
        let mut runner = SweepRunner::new(cpu_factory(), 2).unwrap();
        runner.run_grid(&tiny_grid(), |_, _, _| {}).unwrap();
        assert_eq!(runner.pool().backend_builds(), 2, "one build per worker, ever");
    }
}
