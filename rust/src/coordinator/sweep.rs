//! Design-space sweep orchestration: the full paper grid — and the
//! cross-design comparative grids — cached and sharded.
//!
//! A sweep enumerates every design point of the configured space: for
//! each bit-width, the configured [`DesignSet`] (the paper's
//! `(n, t, fix)` grid, the accurate reference, the related-work
//! baselines, bit-level / netlist spot checks, or all of them) under the
//! configured workload. Every point is evaluated through the persistent
//! [`WorkerPool`] — worker threads hold their backend across all grid
//! points, and the chunk-ordered merge keeps per-config results
//! bit-identical for any worker count. A result cache keyed by
//! [`JobKey`] (canonical design + workload + seed/sample budget) dedups
//! repeated configs across the sweep: the `t = 0` accurate points
//! collapse across fix modes *and* onto the accurate-design baseline,
//! and re-running a grid against a warm runner costs nothing.
//!
//! Above the cache sits the **answer-source layer**: when an
//! [`AnalyticMode`] is enabled, grid points whose design has a
//! registered analytic model ([`crate::error::analytic`]) are answered
//! in O(1) from closed forms — no pool dispatch, no cache entry, counted
//! separately in [`SweepRunner::analytic_answers`]. `auto` serves only
//! `exact: true` models (bit-consistent with exhaustive evaluation);
//! `require` serves every modeled design and errs on unmodeled ones —
//! the zero-dispatch mode for million-config design-space queries.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::Config;
use crate::error::analytic::{analytic_stats, AnalyticStats};
use crate::error::metrics::{ErrorMetrics, ErrorStats};
use crate::error::SegmulError;
use crate::fault::{FaultInjector, RetryCounters, RetryPolicy};
use crate::multiplier::DesignSet;
use crate::store::{Claim, LeaseGuard, ResultStore, StoreKey, StoredResult};

use super::backend::EvalBackend;
use super::job::{EvalJob, JobKey, JobResult, WorkSpec};
use super::pool::WorkerPool;
use super::sharded::ChunkEvent;

/// Where sweep answers may come from (CLI: `--analytic {auto,require,off}`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum AnalyticMode {
    /// Never answer analytically — every point simulates (default; keeps
    /// the sweep a measurement of the evaluation backends).
    #[default]
    Off,
    /// Answer from the analytic registry when the model is **exact**
    /// (`AnalyticStats::exact`); estimate-only families still simulate.
    Auto,
    /// Answer every modeled design analytically (estimates included) and
    /// fail with a typed error on designs without a model: the
    /// zero-dispatch mode.
    Require,
}

impl AnalyticMode {
    /// The CLI name (`--analytic off|auto|require`).
    pub fn name(&self) -> &'static str {
        match self {
            AnalyticMode::Off => "off",
            AnalyticMode::Auto => "auto",
            AnalyticMode::Require => "require",
        }
    }

    /// Parse a CLI / config name.
    pub fn parse(s: &str) -> Result<AnalyticMode, SegmulError> {
        match s.trim() {
            "off" => Ok(AnalyticMode::Off),
            "auto" => Ok(AnalyticMode::Auto),
            "require" => Ok(AnalyticMode::Require),
            other => Err(SegmulError::config(format!(
                "unknown analytic mode {other:?} (auto|require|off)"
            ))),
        }
    }
}

/// One process's slice of a sweep grid (CLI: `--shard i/n`).
///
/// Sharding assigns whole *canonical* job keys, not raw grid rows: the
/// `j`-th distinct [`JobKey`] in grid order belongs to shard
/// `j mod count`. Equivalent rows (the `t = 0` twins, the accurate
/// baseline) therefore land in the same shard and dedup through that
/// shard's cache instead of being evaluated once per shard — N
/// cooperating processes evaluate every key exactly once between them,
/// and the store-backed merge run folds their blobs with zero duplicate
/// evaluations.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Shard {
    /// This process's shard index, `0 <= index < count`.
    pub index: u32,
    /// Total number of cooperating shards.
    pub count: u32,
}

impl Shard {
    /// Parse the CLI form `"i/n"` (e.g. `--shard 0/2`, `--shard 1/2`).
    pub fn parse(s: &str) -> Result<Shard, SegmulError> {
        let (i, n) = s
            .trim()
            .split_once('/')
            .ok_or_else(|| SegmulError::config(format!("bad shard {s:?} (want i/n)")))?;
        let index = i
            .trim()
            .parse::<u32>()
            .map_err(|e| SegmulError::config(format!("bad shard index in {s:?}: {e}")))?;
        let count = n
            .trim()
            .parse::<u32>()
            .map_err(|e| SegmulError::config(format!("bad shard count in {s:?}: {e}")))?;
        if count == 0 {
            return Err(SegmulError::config(format!("bad shard {s:?}: count must be >= 1")));
        }
        if index >= count {
            return Err(SegmulError::config(format!(
                "bad shard {s:?}: index {index} must be < count {count}"
            )));
        }
        Ok(Shard { index, count })
    }

    /// The subset of `jobs` owned by this shard, in original grid order.
    pub fn select(&self, jobs: &[EvalJob]) -> Vec<EvalJob> {
        let mut owner: HashMap<JobKey, u32> = HashMap::new();
        let mut out = Vec::new();
        for job in jobs {
            // Deterministic: ownership follows first-appearance order of
            // the canonical key, which is fixed by the grid itself.
            let next = owner.len() as u32 % self.count;
            let shard = *owner.entry(job.key()).or_insert(next);
            if shard == self.index {
                out.push(job.clone());
            }
        }
        out
    }
}

/// The sweep grid: which design points to evaluate and under which
/// workload. The paper set covers split points `t ∈ 0..n` (0 = accurate)
/// and both fix-to-1 modes, matching the paper's axes; other sets add
/// the comparative designs of Fig. 2.
#[derive(Clone, Debug)]
pub struct SweepGrid {
    /// Operand bit-widths (paper grid: 4, 8, 16, 32).
    pub bitwidths: Vec<u32>,
    /// Design family swept per bit-width.
    pub designs: DesignSet,
    /// Evaluate exhaustively for `n <=` this (capped at 16), MC above.
    pub exhaustive_max_n: u32,
    /// Force Monte-Carlo even below the exhaustive threshold.
    pub force_mc: bool,
    /// MC sample budget per config.
    pub mc_samples: u64,
    /// Base RNG seed shared by every MC config (determinism contract).
    pub seed: u64,
}

impl SweepGrid {
    /// The configured grid from the shared [`Config`] (designs come from
    /// `[sweep] designs`, default the paper set).
    pub fn from_config(cfg: &Config) -> Result<Self, crate::error::SegmulError> {
        Ok(SweepGrid {
            bitwidths: cfg.sweep_bitwidths.clone(),
            designs: DesignSet::parse(&cfg.sweep_designs)?,
            exhaustive_max_n: cfg.exhaustive_max_n,
            force_mc: false,
            mc_samples: cfg.mc_samples,
            seed: cfg.seed,
        })
    }

    /// A single-bit-width slice of the grid.
    pub fn single(n: u32, cfg: &Config) -> Result<Self, crate::error::SegmulError> {
        Ok(SweepGrid { bitwidths: vec![n], ..Self::from_config(cfg)? })
    }

    /// Workload for one bit-width.
    fn spec(&self, n: u32) -> WorkSpec {
        if !self.force_mc && n <= self.exhaustive_max_n.min(16) {
            WorkSpec::Exhaustive
        } else {
            WorkSpec::MonteCarlo { samples: self.mc_samples, seed: self.seed }
        }
    }

    /// Materialize the jobs, in deterministic grid order: for each
    /// bit-width, every design point of the configured set (the paper
    /// set keeps the legacy order: every split point, both modes).
    pub fn jobs(&self) -> Vec<EvalJob> {
        let mut out = Vec::new();
        for &n in &self.bitwidths {
            for design in self.designs.specs(n) {
                out.push(EvalJob { design, spec: self.spec(n) });
            }
        }
        out
    }
}

/// The answer for one grid point: a pool-evaluated (or cache-served)
/// simulation result, or an O(1) closed-form answer from the analytic
/// registry.
#[derive(Clone, Debug)]
pub enum Answer {
    /// A pool-evaluated (or cache-served) result.
    Simulated(JobResult),
    /// A closed-form answer from the analytic registry.
    Analytic {
        stats: AnalyticStats,
        /// Time spent computing the model (microseconds — the bench
        /// `BENCH_analytic.json` gates on this staying so).
        wall: Duration,
    },
}

/// One answered grid point.
#[derive(Clone, Debug)]
pub struct SweepOutcome {
    /// The job as requested by the grid (cache canonicalization may have
    /// served it from an equivalent config's entry).
    pub job: EvalJob,
    /// The answer and its source (simulated, analytic, or store).
    pub answer: Answer,
    /// Served from the result cache (always `false` for analytic
    /// answers — those are counted in [`SweepRunner::analytic_answers`]).
    pub cached: bool,
}

impl SweepOutcome {
    /// The derived metric set, whichever source answered.
    pub fn metrics(&self) -> Result<ErrorMetrics, SegmulError> {
        match &self.answer {
            Answer::Simulated(r) => r.metrics(),
            Answer::Analytic { stats, .. } => Ok(stats.to_metrics()),
        }
    }

    /// The simulation result, when this point was simulated.
    pub fn result(&self) -> Option<&JobResult> {
        match &self.answer {
            Answer::Simulated(r) => Some(r),
            Answer::Analytic { .. } => None,
        }
    }

    /// The analytic answer, when this point was served from the registry.
    pub fn analytic(&self) -> Option<&AnalyticStats> {
        match &self.answer {
            Answer::Simulated(_) => None,
            Answer::Analytic { stats, .. } => Some(stats),
        }
    }

    /// Answer-source tag for reports: `"simulated"` or `"analytic"`.
    pub fn source(&self) -> &'static str {
        match &self.answer {
            Answer::Simulated(_) => "simulated",
            Answer::Analytic { .. } => "analytic",
        }
    }

    /// Wall time spent answering this point.
    pub fn wall(&self) -> Duration {
        match &self.answer {
            Answer::Simulated(r) => r.wall,
            Answer::Analytic { wall, .. } => *wall,
        }
    }
}

/// The closed-form answer for `job` under the `--analytic auto` rules,
/// when its design has an **exact** registered model: validated, O(1),
/// and — crucially — requiring no worker pool at all. This is the
/// degraded-mode answer path of `segmul serve`: a panic storm or backend
/// failure burst takes the pool down, but analytic-eligible requests
/// keep answering from closed forms.
pub fn analytic_outcome(job: &EvalJob) -> Option<SweepOutcome> {
    job.validate().ok()?;
    let start = Instant::now();
    let stats = analytic_stats(&job.design).filter(|s| s.exact)?;
    Some(SweepOutcome {
        job: job.clone(),
        answer: Answer::Analytic { stats, wall: start.elapsed() },
        cached: false,
    })
}

/// Sweep executor: the persistent shard pool + the result cache.
///
/// Workers are spawned once per runner and hold their backend across
/// every grid point (replacing the old per-job backend construction of
/// `run_job_sharded`). The cache is sound because one runner holds one
/// backend factory for its whole lifetime: [`JobKey`] identity only
/// implies identical stats for a fixed backend batch size (see its docs).
pub struct SweepRunner {
    pool: WorkerPool,
    cache_enabled: bool,
    cache: HashMap<JobKey, JobResult>,
    analytic: AnalyticMode,
    /// The persistent result store, when attached ([`Self::set_store`]).
    store: Option<ResultStore>,
    /// How long to wait on another process's lease before evaluating
    /// without exclusion (the duplicate is then deduped at blob commit).
    store_wait: Duration,
    /// Retry accounting for the store/lease layer (the pool's chunk
    /// loop keeps its own: [`WorkerPool::retry_counters`]).
    retry: Arc<RetryCounters>,
    /// Jobs served from the cache (no evaluation).
    pub cache_hits: u64,
    /// Jobs actually evaluated.
    pub jobs_evaluated: u64,
    /// Jobs answered from the analytic registry (no dispatch, no cache).
    pub analytic_answers: u64,
    /// Jobs answered from a committed store blob (no evaluation).
    pub store_hits: u64,
    /// Store degradations recovered from: resumed or discarded chunk
    /// journals and unreadable blobs demoted to re-evaluation.
    pub store_recoveries: u64,
}

impl SweepRunner {
    /// Spawn the persistent pool (`workers` threads; `factory` runs once
    /// in each worker's thread). Fault injection follows the environment
    /// (`SEGMUL_FAULTS`); [`Self::new_with_faults`] takes an explicit
    /// injector.
    pub fn new<F>(factory: F, workers: usize) -> Result<Self>
    where
        F: Fn() -> Result<Box<dyn EvalBackend>> + Send + Sync + 'static,
    {
        Self::new_with_faults(factory, workers, FaultInjector::from_env()?)
    }

    /// [`Self::new`] with an explicit fault injector for the pool (share
    /// the same injector with [`ResultStore::open_with_faults`] so one
    /// account covers every seam).
    pub fn new_with_faults<F>(
        factory: F,
        workers: usize,
        faults: Arc<FaultInjector>,
    ) -> Result<Self>
    where
        F: Fn() -> Result<Box<dyn EvalBackend>> + Send + Sync + 'static,
    {
        Ok(SweepRunner {
            pool: WorkerPool::start_with_faults(factory, workers, faults)?,
            cache_enabled: true,
            cache: HashMap::new(),
            analytic: AnalyticMode::default(),
            store: None,
            store_wait: Duration::from_secs(600),
            retry: Arc::new(RetryCounters::new()),
            cache_hits: 0,
            jobs_evaluated: 0,
            analytic_answers: 0,
            store_hits: 0,
            store_recoveries: 0,
        })
    }

    /// Retry accounting for this runner's store/lease layer.
    pub fn lease_retry_counters(&self) -> &RetryCounters {
        &self.retry
    }

    /// Worker threads in the pool.
    pub fn workers(&self) -> usize {
        self.pool.pool_size()
    }

    /// The persistent pool backing this runner.
    pub fn pool(&self) -> &WorkerPool {
        &self.pool
    }

    /// Disable (or re-enable) the result cache — every job re-evaluates.
    pub fn set_cache_enabled(&mut self, enabled: bool) {
        self.cache_enabled = enabled;
    }

    /// Set the answer-source policy (default [`AnalyticMode::Off`]).
    pub fn set_analytic_mode(&mut self, mode: AnalyticMode) {
        self.analytic = mode;
    }

    /// The current answer-source policy.
    pub fn analytic_mode(&self) -> AnalyticMode {
        self.analytic
    }

    /// Attach a persistent result store: committed blobs answer before
    /// the pool, chunk journals checkpoint every running job (so a
    /// killed sweep resumes bit-identically), and per-key leases keep
    /// cooperating processes from evaluating a key twice.
    pub fn set_store(&mut self, store: ResultStore) {
        self.store = Some(store);
    }

    /// The attached store, if any.
    pub fn store(&self) -> Option<&ResultStore> {
        self.store.as_ref()
    }

    /// Bound the wait on another live process's lease (default 600 s);
    /// past it this process evaluates without exclusion — correct either
    /// way, the lease only prevents duplicated work.
    pub fn set_store_wait(&mut self, wait: Duration) {
        self.store_wait = wait;
    }

    /// Evaluate one job, consulting the analytic registry and the cache
    /// first.
    pub fn run(&mut self, job: &EvalJob) -> Result<SweepOutcome> {
        self.run_observed(job, &mut |_| {})
    }

    /// The analytic answer for `job` under the configured mode, if that
    /// mode elects to serve it. `Require` turns a missing model into a
    /// typed config error naming the design.
    fn analytic_answer(&self, job: &EvalJob) -> Result<Option<AnalyticStats>, SegmulError> {
        match self.analytic {
            AnalyticMode::Off => Ok(None),
            AnalyticMode::Auto => {
                Ok(analytic_stats(&job.design).filter(|s| s.exact))
            }
            AnalyticMode::Require => match analytic_stats(&job.design) {
                Some(s) => Ok(Some(s)),
                None => Err(SegmulError::config(format!(
                    "--analytic require: no analytic model for design {}",
                    job.design.name()
                ))),
            },
        }
    }

    /// Whether the configured mode will answer `job` analytically (so
    /// callers can skip backend preflight for points that never reach
    /// the pool). `Require` failures surface at [`Self::run`].
    pub fn will_answer_analytically(&self, job: &EvalJob) -> bool {
        matches!(self.analytic_answer(job), Ok(Some(_)))
    }

    /// [`Self::run`], streaming in-order chunk merges to `observer`
    /// (analytic answers and cache hits complete without chunk events).
    pub fn run_observed(
        &mut self,
        job: &EvalJob,
        observer: &mut dyn FnMut(ChunkEvent),
    ) -> Result<SweepOutcome> {
        // Answer-source layer: closed forms beat both cache and pool.
        let analytic_start = Instant::now();
        if let Some(stats) = self.analytic_answer(job)? {
            self.analytic_answers += 1;
            return Ok(SweepOutcome {
                job: job.clone(),
                answer: Answer::Analytic { stats, wall: analytic_start.elapsed() },
                cached: false,
            });
        }
        let key = job.key();
        if self.cache_enabled {
            if let Some(hit) = self.cache.get(&key) {
                self.cache_hits += 1;
                // The entry may have been evaluated under an equivalent
                // design (canonicalization); report the requested one.
                let mut result = hit.clone();
                result.job = job.clone();
                return Ok(SweepOutcome {
                    job: job.clone(),
                    answer: Answer::Simulated(result),
                    cached: true,
                });
            }
        }
        if self.store.is_some() {
            return self.run_via_store(job, key, observer);
        }
        let result = self.pool.run_job_observed(job, observer)?;
        self.jobs_evaluated += 1;
        if self.cache_enabled {
            self.cache.insert(key, result.clone());
        }
        Ok(SweepOutcome { job: job.clone(), answer: Answer::Simulated(result), cached: false })
    }

    /// Load the committed blob for `skey`, degrading any corruption
    /// (truncation, bit flip, schema or key mismatch — a typed
    /// [`SegmulError::Store`]) to a counted miss: the job re-evaluates
    /// and the store can never serve a silently wrong answer.
    fn store_probe(&mut self, skey: &StoreKey) -> Option<StoredResult> {
        match self.store.as_ref()?.load(skey) {
            Ok(hit) => hit,
            Err(e) => {
                eprintln!("warning: {e}; treating the entry as a miss and re-evaluating");
                self.store_recoveries += 1;
                None
            }
        }
    }

    /// Present a committed store blob as this runner's answer. It seeds
    /// the in-memory cache (so canonical twins of the key still register
    /// as `cached`, keeping cache accounting identical to an
    /// uninterrupted run) but itself reports `cached: false` — a store
    /// hit *is* the persisted evaluation, not a repeat of one.
    fn outcome_from_store(&mut self, job: &EvalJob, key: JobKey, hit: StoredResult) -> SweepOutcome {
        let result = JobResult {
            job: job.clone(),
            stats: hit.stats,
            // Sound: the backend name is part of the store key, so the
            // blob was produced by a backend of this very name.
            backend: self.pool.backend_name(),
            wall: hit.wall,
            batches: hit.batches,
        };
        if self.cache_enabled {
            self.cache.insert(key, result.clone());
        }
        SweepOutcome { job: job.clone(), answer: Answer::Simulated(result), cached: false }
    }

    /// The store-backed evaluation path: blob fast path, per-key lease,
    /// journal-checkpointed (and journal-resumed) pool run, atomic blob
    /// commit.
    fn run_via_store(
        &mut self,
        job: &EvalJob,
        key: JobKey,
        observer: &mut dyn FnMut(ChunkEvent),
    ) -> Result<SweepOutcome> {
        let skey = StoreKey::new(job, self.pool.backend_name(), self.pool.batch());
        // Fast path: a previously committed blob answers without pool
        // dispatch (and without the lease).
        if let Some(hit) = self.store_probe(&skey) {
            self.store_hits += 1;
            return Ok(self.outcome_from_store(job, key, hit));
        }
        // Claim the key's lease under the typed lease retry policy: a
        // busy holder and a transient lease I/O failure both back off
        // with bounded, deterministically jittered delays and re-poll
        // for the holder's committed blob, the whole episode capped by
        // `store_wait`. Past the budget this process evaluates without
        // exclusion — correct either way, the lease only prevents
        // duplicated work (the duplicate dedups at blob commit).
        enum LeaseWait {
            Acquired(LeaseGuard),
            Committed(StoredResult),
            /// The lease layer itself kept failing (broken leases dir,
            /// exhausted transient-fault budget): proceed unprotected
            /// after a *small* bounded number of claim retries — never
            /// the full `store_wait`.
            Unprotected(SegmulError),
        }
        let counters = self.retry.clone();
        let mut claim_errors = 0u32;
        let wait = RetryPolicy::lease(self.store_wait).run(&counters, |_attempt| {
            let claim = match self.store.as_ref() {
                Some(s) => s.claim(&skey),
                None => Err(SegmulError::store(skey.address(), "store detached mid-run")),
            };
            match claim {
                Ok(Claim::Acquired(g)) => Ok(LeaseWait::Acquired(g)),
                Ok(Claim::Busy) => {
                    claim_errors = 0;
                    match self.store_probe(&skey) {
                        Some(hit) => Ok(LeaseWait::Committed(hit)),
                        None => Err(SegmulError::store(
                            skey.address(),
                            "lease busy: waiting for the holder's commit",
                        )),
                    }
                }
                Err(e) => {
                    claim_errors += 1;
                    if claim_errors >= 4 {
                        Ok(LeaseWait::Unprotected(e))
                    } else {
                        Err(e)
                    }
                }
            }
        });
        let mut guard = None;
        match wait {
            Ok(LeaseWait::Acquired(g)) => guard = Some(g),
            Ok(LeaseWait::Committed(hit)) => {
                self.store_hits += 1;
                return Ok(self.outcome_from_store(job, key, hit));
            }
            Ok(LeaseWait::Unprotected(e)) => {
                eprintln!(
                    "warning: lease for key {} unavailable ({e}); evaluating without exclusion",
                    skey.address()
                );
            }
            Err(e) => {
                eprintln!(
                    "warning: lease wait for key {} gave up ({e}); evaluating without exclusion",
                    skey.address()
                );
            }
        }
        // Resume from the key's checkpointed chunk prefix (empty for a
        // fresh key) and journal every newly merged chunk, in merge
        // order, behind the cursor.
        let Some(store) = self.store.as_ref() else {
            return Err(SegmulError::store(skey.address(), "store detached mid-run").into());
        };
        let journal = store.recover_journal(&skey);
        if !journal.chunks.is_empty() || journal.discarded_bytes > 0 {
            self.store_recoveries += 1;
        }
        let mut writer = match store.journal_writer(&skey, journal.valid_len) {
            Ok(w) => Some(w),
            Err(e) => {
                eprintln!("warning: run will not checkpoint: {e}");
                None
            }
        };
        let mut sink = |chunk_id: u64, stats: &ErrorStats| {
            if let Some(w) = writer.as_mut() {
                w.append(chunk_id, stats);
            }
        };
        let result = self.pool.run_job_checkpointed(job, &journal.chunks, observer, Some(&mut sink))?;
        self.jobs_evaluated += 1;
        if let Err(e) = store.commit(&skey, &result) {
            eprintln!("warning: {e}; result stays correct but was not persisted");
        }
        drop(guard);
        if self.cache_enabled {
            self.cache.insert(key, result.clone());
        }
        Ok(SweepOutcome { job: job.clone(), answer: Answer::Simulated(result), cached: false })
    }

    /// Run an explicit job list in order, streaming progress through
    /// `progress` (called once per completed point with
    /// `(index, total, outcome)`). This is the grid path and the sharded
    /// path — each cooperating process runs its [`Shard::select`] slice.
    pub fn run_jobs(
        &mut self,
        jobs: &[EvalJob],
        mut progress: impl FnMut(usize, usize, &SweepOutcome),
    ) -> Result<Vec<SweepOutcome>> {
        let total = jobs.len();
        let mut out = Vec::with_capacity(total);
        for (i, job) in jobs.iter().enumerate() {
            let outcome = self.run(job)?;
            progress(i, total, &outcome);
            out.push(outcome);
        }
        Ok(out)
    }

    /// Run a whole grid in order ([`Self::run_jobs`] over [`SweepGrid::jobs`]).
    pub fn run_grid(
        &mut self,
        grid: &SweepGrid,
        progress: impl FnMut(usize, usize, &SweepOutcome),
    ) -> Result<Vec<SweepOutcome>> {
        self.run_jobs(&grid.jobs(), progress)
    }
}

#[cfg(test)]
mod tests {
    use std::sync::atomic::{AtomicUsize, Ordering};

    use super::*;
    use crate::coordinator::backend::CpuBackend;
    use crate::multiplier::MultiplierSpec;

    fn cpu_factory() -> impl Fn() -> Result<Box<dyn EvalBackend>> + Send + Sync + 'static {
        || Ok(Box::new(CpuBackend::new()) as Box<dyn EvalBackend>)
    }

    fn tiny_grid() -> SweepGrid {
        SweepGrid {
            bitwidths: vec![4, 6],
            designs: DesignSet::Paper,
            exhaustive_max_n: 6,
            force_mc: false,
            mc_samples: 10_000,
            seed: 3,
        }
    }

    #[test]
    fn grid_enumerates_all_points() {
        let jobs = tiny_grid().jobs();
        // (4 + 6 split points) x 2 modes.
        assert_eq!(jobs.len(), (4 + 6) * 2);
        assert!(jobs.iter().all(|j| matches!(j.spec, WorkSpec::Exhaustive)));
        let mc = SweepGrid { force_mc: true, ..tiny_grid() };
        assert!(mc.jobs().iter().all(|j| matches!(j.spec, WorkSpec::MonteCarlo { .. })));
    }

    #[test]
    fn cross_design_grid_enumerates_every_family() {
        let grid = SweepGrid { designs: DesignSet::All, bitwidths: vec![4], ..tiny_grid() };
        let jobs = grid.jobs();
        // paper (8) + accurate (1) + baselines (5: n=4 is a power of two)
        // + oracle (1) + netlist (1).
        assert_eq!(jobs.len(), 16);
        assert!(jobs.iter().any(|j| matches!(j.design, MultiplierSpec::Mitchell { .. })));
        assert!(jobs.iter().any(|j| matches!(j.design, MultiplierSpec::Netlist { .. })));
    }

    #[test]
    fn cache_dedups_t0_modes_and_repeats() {
        let grid = tiny_grid();
        let mut runner = SweepRunner::new(cpu_factory(), 2).unwrap();
        let outcomes = runner.run_grid(&grid, |_, _, _| {}).unwrap();
        assert_eq!(outcomes.len(), 20);
        // Each bit-width's (t=0, fix=true) point is served from the
        // (t=0, fix=false) entry.
        assert_eq!(runner.cache_hits, 2);
        assert_eq!(runner.jobs_evaluated, 18);
        // Re-running the same grid is fully cached.
        let again = runner.run_grid(&grid, |_, _, _| {}).unwrap();
        assert_eq!(runner.jobs_evaluated, 18);
        assert_eq!(runner.cache_hits, 2 + 20);
        assert!(again.iter().all(|o| o.cached));
        // Cached results are the same statistics objects.
        for (a, b) in outcomes.iter().zip(&again) {
            assert_eq!(a.result().unwrap().stats, b.result().unwrap().stats);
        }
        // No analytic mode configured: every answer is a simulation.
        assert_eq!(runner.analytic_answers, 0);
        assert!(outcomes.iter().all(|o| o.source() == "simulated"));
    }

    #[test]
    fn cache_dedups_accurate_design_against_t0_points() {
        // Cross-design canonicalization: the accurate baseline shares the
        // paper grid's t=0 entry.
        let mut runner = SweepRunner::new(cpu_factory(), 1).unwrap();
        let t0 = runner.run(&EvalJob::exhaustive(6, 0, true)).unwrap();
        assert!(!t0.cached);
        let accurate = runner
            .run(&EvalJob::new(MultiplierSpec::Accurate { n: 6 }, WorkSpec::Exhaustive))
            .unwrap();
        assert!(accurate.cached, "accurate must be served from the t=0 entry");
        assert_eq!(accurate.result().unwrap().stats, t0.result().unwrap().stats);
        assert_eq!(runner.jobs_evaluated, 1);
    }

    #[test]
    fn cache_hits_do_not_touch_the_backend() {
        use std::sync::Arc;
        let evals = Arc::new(AtomicUsize::new(0));
        struct Counting {
            inner: CpuBackend,
            evals: Arc<AtomicUsize>,
        }
        impl EvalBackend for Counting {
            fn name(&self) -> &'static str {
                "counting"
            }
            fn max_batch(&self) -> usize {
                self.inner.max_batch()
            }
            fn supports(&self, n: u32) -> bool {
                self.inner.supports(n)
            }
            fn eval_batch(
                &mut self,
                n: u32,
                t: u32,
                fix: bool,
                a: &[u64],
                b: &[u64],
            ) -> Result<crate::error::metrics::ErrorStats> {
                self.evals.fetch_add(1, Ordering::Relaxed);
                self.inner.eval_batch(n, t, fix, a, b)
            }
        }
        let counter = evals.clone();
        let factory = move || {
            Ok(Box::new(Counting { inner: CpuBackend::new(), evals: counter.clone() })
                as Box<dyn EvalBackend>)
        };
        let mut runner = SweepRunner::new(factory, 1).unwrap();
        let job = EvalJob::mc(8, 4, true, 50_000, 1);
        let first = runner.run(&job).unwrap();
        let after_first = evals.load(Ordering::Relaxed);
        assert!(!first.cached && after_first > 0);
        let second = runner.run(&job).unwrap();
        assert!(second.cached);
        assert_eq!(evals.load(Ordering::Relaxed), after_first, "cache hit re-evaluated");
        assert_eq!(first.result().unwrap().stats, second.result().unwrap().stats);
    }

    #[test]
    fn cache_can_be_disabled() {
        let mut runner = SweepRunner::new(cpu_factory(), 1).unwrap();
        runner.set_cache_enabled(false);
        let job = EvalJob::mc(8, 4, true, 20_000, 1);
        let a = runner.run(&job).unwrap();
        let b = runner.run(&job).unwrap();
        assert!(!a.cached && !b.cached);
        assert_eq!(runner.jobs_evaluated, 2);
        assert_eq!(runner.cache_hits, 0);
        assert_eq!(a.result().unwrap().stats, b.result().unwrap().stats);
    }

    #[test]
    fn grid_results_deterministic_across_worker_counts() {
        // > 2 chunks of 2^16 per config so the stealing cursor interleaves.
        let grid = SweepGrid { force_mc: true, mc_samples: 150_000, ..tiny_grid() };
        let run = |workers| {
            let mut r = SweepRunner::new(cpu_factory(), workers).unwrap();
            r.run_grid(&grid, |_, _, _| {}).unwrap()
        };
        let w1 = run(1);
        let w3 = run(3);
        for (a, b) in w1.iter().zip(&w3) {
            assert_eq!(
                a.result().unwrap().stats,
                b.result().unwrap().stats,
                "design={}",
                a.job.design.name()
            );
        }
    }

    #[test]
    fn runner_backends_persist_across_grid_points() {
        let mut runner = SweepRunner::new(cpu_factory(), 2).unwrap();
        runner.run_grid(&tiny_grid(), |_, _, _| {}).unwrap();
        assert_eq!(runner.pool().backend_builds(), 2, "one build per worker, ever");
    }

    #[test]
    fn shard_parsing() {
        assert_eq!(Shard::parse("0/2").unwrap(), Shard { index: 0, count: 2 });
        assert_eq!(Shard::parse(" 1/2 ").unwrap(), Shard { index: 1, count: 2 });
        assert_eq!(Shard::parse("0/1").unwrap(), Shard { index: 0, count: 1 });
        for bad in ["", "1", "2/2", "3/2", "-1/2", "0/0", "a/b", "1/2/3"] {
            assert_eq!(Shard::parse(bad).unwrap_err().kind(), "config", "{bad}");
        }
    }

    #[test]
    fn shards_partition_canonical_keys_exactly() {
        let grid = tiny_grid();
        let jobs = grid.jobs();
        for count in [1u32, 2, 3, 7] {
            let mut seen: HashMap<JobKey, u32> = HashMap::new();
            let mut total = 0usize;
            for index in 0..count {
                let slice = Shard { index, count }.select(&jobs);
                total += slice.len();
                for job in &slice {
                    // A canonical key never appears in two shards: the
                    // t=0 twins travel together, so no key is ever
                    // evaluated by two cooperating processes.
                    let owner = seen.entry(job.key()).or_insert(index);
                    assert_eq!(*owner, index, "count={count} key in two shards");
                }
            }
            // Every grid row lands in exactly one shard.
            assert_eq!(total, jobs.len(), "count={count}");
            let distinct: std::collections::HashSet<_> =
                jobs.iter().map(|j| j.key()).collect();
            assert_eq!(seen.len(), distinct.len(), "count={count}");
        }
        // One shard is the whole grid, in order.
        let all = Shard { index: 0, count: 1 }.select(&jobs);
        assert_eq!(all.len(), jobs.len());
    }

    #[test]
    fn store_serves_committed_results_across_runners() {
        let dir =
            std::env::temp_dir().join(format!("segmul-sweep-store-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let job = EvalJob::mc(8, 4, true, 120_000, 5);
        let mut first = SweepRunner::new(cpu_factory(), 2).unwrap();
        first.set_store(ResultStore::open(&dir).unwrap());
        let a = first.run(&job).unwrap();
        assert_eq!(first.jobs_evaluated, 1);
        assert_eq!(first.store_hits, 0);
        // A brand-new runner (cold in-memory cache) answers from the
        // committed blob without touching the pool.
        let mut second = SweepRunner::new(cpu_factory(), 3).unwrap();
        second.set_store(ResultStore::open(&dir).unwrap());
        let b = second.run(&job).unwrap();
        assert_eq!(second.jobs_evaluated, 0);
        assert_eq!(second.store_hits, 1);
        assert!(!b.cached, "store hits present as fresh answers");
        assert_eq!(a.result().unwrap().stats, b.result().unwrap().stats);
        assert_eq!(
            a.result().unwrap().stats.sum_red.to_bits(),
            b.result().unwrap().stats.sum_red.to_bits()
        );
        // The store hit seeded the in-memory cache, so a repeat is a
        // cache hit — cache accounting stays identical to an
        // uninterrupted run.
        let c = second.run(&job).unwrap();
        assert!(c.cached);
        assert_eq!(second.cache_hits, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn analytic_outcome_serves_exact_models_without_a_pool() {
        // The serve degraded path: closed-form answers with no workers.
        let job = EvalJob::new(MultiplierSpec::Truncated { n: 8, k: 4 }, WorkSpec::Exhaustive);
        let out = analytic_outcome(&job).unwrap();
        assert_eq!(out.source(), "analytic");
        assert!(!out.cached);
        assert_eq!(out.analytic().unwrap().wce, 49);
        // Estimate-only families and invalid designs are refused.
        assert!(analytic_outcome(&EvalJob::exhaustive(6, 3, true)).is_none());
        let bad = EvalJob::new(MultiplierSpec::Kulkarni { n: 12 }, WorkSpec::Exhaustive);
        assert!(analytic_outcome(&bad).is_none());
    }

    #[test]
    fn busy_lease_waits_with_retries_then_degrades_to_unprotected_eval() {
        let dir =
            std::env::temp_dir().join(format!("segmul-lease-wait-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let job = EvalJob::mc(8, 4, true, 60_000, 5);
        let mut runner = SweepRunner::new(cpu_factory(), 1).unwrap();
        runner.set_store(ResultStore::open(&dir).unwrap());
        runner.set_store_wait(Duration::from_millis(120));
        // A live foreign holder (pid 1: the namespace init, never ours)
        // pins the lease and never commits.
        let skey = StoreKey::new(&job, "cpu", runner.pool().batch());
        let lease = runner.store().unwrap().lease_path(&skey);
        std::fs::write(&lease, "1\n").unwrap();
        let out = runner.run(&job).unwrap();
        assert_eq!(runner.jobs_evaluated, 1, "must degrade to unprotected evaluation");
        assert!(out.result().is_some());
        assert!(runner.lease_retry_counters().retries() > 0, "waiting goes through retries");
        assert_eq!(runner.lease_retry_counters().gave_up(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn analytic_mode_parsing() {
        assert_eq!(AnalyticMode::parse("auto").unwrap(), AnalyticMode::Auto);
        assert_eq!(AnalyticMode::parse(" require ").unwrap(), AnalyticMode::Require);
        assert_eq!(AnalyticMode::parse("off").unwrap(), AnalyticMode::Off);
        assert_eq!(AnalyticMode::parse("maybe").unwrap_err().kind(), "config");
        for mode in [AnalyticMode::Off, AnalyticMode::Auto, AnalyticMode::Require] {
            assert_eq!(AnalyticMode::parse(mode.name()).unwrap(), mode);
        }
        assert_eq!(AnalyticMode::default(), AnalyticMode::Off);
    }

    #[test]
    fn analytic_auto_serves_exact_models_only() {
        let mut runner = SweepRunner::new(cpu_factory(), 1).unwrap();
        runner.set_analytic_mode(AnalyticMode::Auto);
        // Exact closed form (truncation, n <= 16): answered analytically.
        let trunc = runner
            .run(&EvalJob::new(MultiplierSpec::Truncated { n: 8, k: 4 }, WorkSpec::Exhaustive))
            .unwrap();
        assert_eq!(trunc.source(), "analytic");
        assert!(!trunc.cached);
        assert!(trunc.result().is_none());
        assert_eq!(trunc.analytic().unwrap().wce, 49);
        // Estimate-only family (segmented, t > 0): still simulated.
        let seg = runner.run(&EvalJob::exhaustive(6, 3, true)).unwrap();
        assert_eq!(seg.source(), "simulated");
        assert_eq!(runner.analytic_answers, 1);
        assert_eq!(runner.jobs_evaluated, 1);
        // Analytic answers bypass the cache entirely.
        runner.run(&EvalJob::new(MultiplierSpec::Truncated { n: 8, k: 4 }, WorkSpec::Exhaustive))
            .unwrap();
        assert_eq!(runner.analytic_answers, 2);
        assert_eq!(runner.cache_hits, 0);
    }

    #[test]
    fn analytic_auto_matches_exhaustive_exactly_for_closed_form_families() {
        // The acceptance contract: an auto-served row is bit-consistent
        // with the simulated row for the exactly-modeled metrics.
        let job = EvalJob::new(MultiplierSpec::Truncated { n: 6, k: 3 }, WorkSpec::Exhaustive);
        let mut sim = SweepRunner::new(cpu_factory(), 2).unwrap();
        let simulated = sim.run(&job).unwrap().metrics().unwrap();
        let mut fast = SweepRunner::new(cpu_factory(), 1).unwrap();
        fast.set_analytic_mode(AnalyticMode::Auto);
        let analytic = fast.run(&job).unwrap().metrics().unwrap();
        assert_eq!(fast.jobs_evaluated, 0);
        assert_eq!(analytic.er, simulated.er);
        assert_eq!(analytic.med_abs, simulated.med_abs);
        assert_eq!(analytic.mae, simulated.mae);
        assert_eq!(analytic.samples, simulated.samples);
        assert!((analytic.mred - simulated.mred).abs() <= 1e-9 * simulated.mred);
    }

    #[test]
    fn analytic_require_answers_full_grid_with_zero_dispatch() {
        let grid = SweepGrid {
            bitwidths: vec![4, 8],
            designs: DesignSet::All,
            ..tiny_grid()
        };
        let mut runner = SweepRunner::new(cpu_factory(), 1).unwrap();
        runner.set_analytic_mode(AnalyticMode::Require);
        let outcomes = runner.run_grid(&grid, |_, _, _| {}).unwrap();
        assert_eq!(runner.jobs_evaluated, 0, "require mode must not dispatch");
        assert_eq!(runner.cache_hits, 0);
        assert_eq!(runner.analytic_answers, outcomes.len() as u64);
        assert!(outcomes.iter().all(|o| o.source() == "analytic"));
        // Every answer derives a finite metric set.
        for o in &outcomes {
            let m = o.metrics().unwrap();
            assert!(m.er.is_finite() && m.mred.is_finite(), "{}", o.job.design.name());
        }
    }

    #[test]
    fn analytic_require_rejects_unmodeled_designs() {
        let mut runner = SweepRunner::new(cpu_factory(), 1).unwrap();
        runner.set_analytic_mode(AnalyticMode::Require);
        // Invalid spec => no model => typed config error naming it.
        let bad = EvalJob::new(MultiplierSpec::Kulkarni { n: 12 }, WorkSpec::Exhaustive);
        let err = runner.run(&bad).unwrap_err().to_string();
        assert!(err.contains("kulkarni(n=12)"), "{err}");
        assert!(err.contains("analytic"), "{err}");
    }
}
