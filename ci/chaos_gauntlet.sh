#!/usr/bin/env bash
# Chaos gauntlet: prove the fault-injection robustness guarantees
# end-to-end, against the real binary.
#
#   1. Fault storm — a store-backed sweep run under an aggressive
#      SEGMUL_FAULTS plan (I/O failures, blob corruption, journal-append
#      failures, lease contention, worker panics) must complete, report
#      its injections, and write reports byte-identical to a fault-free
#      reference run: injected faults are slower, never wrong.
#   2. Fleet kill-and-heal — `segmul fleet` supervising two sharded
#      workers over one store, with shard 0 SIGKILLed at spawn, must
#      restart the victim, drain both shards, and merge to the
#      reference bytes.
#
# All runs use --deterministic-report so sweep.csv + BENCH_sweep.json
# carry no wall-clock fields and can be compared with `cmp`.
#
# Usage: ci/chaos_gauntlet.sh   (from the repo root; needs a release
# build — set SEGMUL to override the binary path, SAMPLES/DESIGNS to
# resize the workload, SEGMUL_CHAOS to override the storm plan).
set -euo pipefail
cd "$(dirname "$0")/../rust"

SEGMUL="${SEGMUL:-target/release/segmul}"
SAMPLES="${SAMPLES:-2000000}"
DESIGNS="${DESIGNS:-paper}"
CHAOS="${SEGMUL_CHAOS:-store.read:p=0.1,store.write:p=0.1,store.corrupt:p=0.1,journal.append:p=0.1,lease.claim:p=0.1,worker.panic:p=0.02}"
WORK="$(mktemp -d)"
trap 'rm -rf "$WORK"' EXIT

sweep() {
    "$SEGMUL" sweep --designs "$DESIGNS" --mc --samples "$SAMPLES" --seed 42 \
        --deterministic-report "$@"
}

echo "== reference: fault-free, no store, 2 workers =="
sweep --workers 2 --results "$WORK/ref" | tee "$WORK/ref.log"

echo "== fault storm: store-backed sweep under SEGMUL_FAULTS=$CHAOS =="
SEGMUL_FAULTS="$CHAOS" SEGMUL_FAULT_SEED=3405691582 \
    sweep --workers 2 --store "$WORK/store" --results "$WORK/chaos" | tee "$WORK/chaos.log"
grep -q "faults_injected:" "$WORK/chaos.log" || {
    echo "FAIL: the chaos plan never fired (no faults_injected line)"
    exit 1
}
cmp "$WORK/ref/sweep.csv" "$WORK/chaos/sweep.csv"
cmp "$WORK/ref/BENCH_sweep.json" "$WORK/chaos/BENCH_sweep.json"
echo "PASS: storm run reports are byte-identical to the fault-free reference"

echo "== fleet: two supervised shards over one store; shard 0 SIGKILLed at spawn =="
"$SEGMUL" fleet --shards 2 --designs "$DESIGNS" --mc --samples "$SAMPLES" --seed 42 \
    --workers 2 --store "$WORK/fstore" --results "$WORK/fleet" >"$WORK/fleet.log" 2>&1 &
FLEET=$!
SHARD_PID=""
for _ in $(seq 1 600); do
    SHARD_PID=$(sed -n 's|^fleet: shard 0/2 pid \([0-9][0-9]*\) up (restart #0).*|\1|p' "$WORK/fleet.log" | head -n 1)
    [ -n "$SHARD_PID" ] && break
    kill -0 "$FLEET" 2>/dev/null || break
    sleep 0.05
done
if [ -n "$SHARD_PID" ] && kill -9 "$SHARD_PID" 2>/dev/null; then
    echo "SIGKILLed shard 0 (pid $SHARD_PID)"
    EXPECT_RESTART=1
else
    echo "shard 0 finished before the kill landed"
    EXPECT_RESTART=0
fi
wait "$FLEET"
cat "$WORK/fleet.log"
if [ "$EXPECT_RESTART" -eq 1 ]; then
    grep -q "restart #1" "$WORK/fleet.log" || {
        echo "FAIL: the killed shard was never restarted"
        exit 1
    }
fi
grep -q "merge complete" "$WORK/fleet.log" || {
    echo "FAIL: the fleet never ran its merge pass"
    exit 1
}
cmp "$WORK/ref/sweep.csv" "$WORK/fleet/sweep.csv"
cmp "$WORK/ref/BENCH_sweep.json" "$WORK/fleet/BENCH_sweep.json"
echo "PASS: the healed fleet merged to the reference bytes"
