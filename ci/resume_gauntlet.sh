#!/usr/bin/env bash
# Resume gauntlet: prove the result store's two headline guarantees
# end-to-end, against the real binary, with a real SIGKILL.
#
#   1. Kill-and-resume — a sweep SIGKILLed mid-grid and resumed with
#      `--resume` (on a different worker count) writes byte-identical
#      reports to an uninterrupted reference run.
#   2. Sharding — two concurrent processes claiming disjoint shards of
#      the grid (`--shard 0/2` / `--shard 1/2`) into one shared store,
#      followed by a merge run, reproduce the reference bytes with zero
#      duplicate evaluations across all three processes.
#
# All runs use --deterministic-report so sweep.csv + BENCH_sweep.json
# carry no wall-clock fields and can be compared with `cmp`.
#
# Usage: ci/resume_gauntlet.sh   (from the repo root; needs a release
# build — set SEGMUL to override the binary path, SAMPLES/DESIGNS to
# resize the workload).
set -euo pipefail
cd "$(dirname "$0")/../rust"

SEGMUL="${SEGMUL:-target/release/segmul}"
SAMPLES="${SAMPLES:-2000000}"
DESIGNS="${DESIGNS:-paper}"
WORK="$(mktemp -d)"
trap 'rm -rf "$WORK"' EXIT

sweep() {
    "$SEGMUL" sweep --designs "$DESIGNS" --mc --samples "$SAMPLES" --seed 42 \
        --deterministic-report "$@"
}

# Pull the "N evaluated" count out of a sweep summary line.
evaluated() {
    sed -n 's/.* s (\([0-9][0-9]*\) evaluated,.*/\1/p' "$1" | tail -n 1
}

echo "== reference: uninterrupted, no store, 2 workers =="
sweep --workers 2 --results "$WORK/ref" | tee "$WORK/ref.log"

echo "== victim: store-backed, SIGKILLed mid-grid =="
STORE="$WORK/store"
sweep --workers 2 --store "$STORE" --results "$WORK/victim" >"$WORK/victim.log" 2>&1 &
VICTIM=$!
blobs=0
for _ in $(seq 1 300); do
    blobs=$(find "$STORE/blobs" -name '*.json' 2>/dev/null | wc -l)
    [ "$blobs" -ge 3 ] && break
    kill -0 "$VICTIM" 2>/dev/null || break
    sleep 0.1
done
if kill -9 "$VICTIM" 2>/dev/null; then
    echo "SIGKILLed victim with $blobs results committed"
else
    echo "victim finished before the kill landed ($blobs results committed)"
fi
wait "$VICTIM" 2>/dev/null || true

echo "== resume: same store, 7 workers =="
sweep --workers 7 --store "$STORE" --resume --results "$WORK/resume" | tee "$WORK/resume.log"
cmp "$WORK/ref/sweep.csv" "$WORK/resume/sweep.csv"
cmp "$WORK/ref/BENCH_sweep.json" "$WORK/resume/BENCH_sweep.json"
echo "PASS: resumed reports are byte-identical to the uninterrupted reference"

echo "== sharded: two concurrent processes, disjoint shards, one store =="
STORE2="$WORK/store2"
sweep --workers 2 --store "$STORE2" --shard 0/2 --results "$WORK/shard0" \
    >"$WORK/shard0.log" 2>&1 &
SHARD0=$!
sweep --workers 2 --store "$STORE2" --shard 1/2 --results "$WORK/shard1" | tee "$WORK/shard1.log"
wait "$SHARD0"
cat "$WORK/shard0.log"

echo "== merge: same store, no shard — must be pure store hits =="
sweep --workers 2 --store "$STORE2" --resume --results "$WORK/merge" | tee "$WORK/merge.log"
cmp "$WORK/ref/sweep.csv" "$WORK/merge/sweep.csv"
cmp "$WORK/ref/BENCH_sweep.json" "$WORK/merge/BENCH_sweep.json"

ref_evals=$(evaluated "$WORK/ref.log")
shard0_evals=$(evaluated "$WORK/shard0.log")
shard1_evals=$(evaluated "$WORK/shard1.log")
merge_evals=$(evaluated "$WORK/merge.log")
echo "evaluations: reference=$ref_evals shard0=$shard0_evals shard1=$shard1_evals merge=$merge_evals"
[ "$merge_evals" -eq 0 ] || { echo "FAIL: merge run re-evaluated $merge_evals jobs"; exit 1; }
[ $((shard0_evals + shard1_evals)) -eq "$ref_evals" ] || {
    echo "FAIL: shards evaluated $((shard0_evals + shard1_evals)) jobs, reference needed $ref_evals"
    exit 1
}
echo "PASS: sharded runs merged to reference bytes with zero duplicate evaluations"
