#!/usr/bin/env bash
# Serve smoke: prove the HTTP front end's headline behavior end-to-end,
# against the real release binary, with real sockets.
#
#   1. Every endpoint answers: /healthz, /v1/designs, /metrics, /v1/eval,
#      /v1/sweep (chunked ndjson), /v1/shutdown.
#   2. The backend identity is machine-readable: the CLI prints a
#      `backend: <name>` line and /metrics carries `serve_backend`.
#   3. A burst of identical eval requests coalesces: strictly fewer pool
#      dispatches than requests on /metrics.
#   4. Malformed requests get typed JSON 4xx errors; the server survives.
#   5. A saturating burst against a tiny --max-inflight budget yields
#      typed 429s — never a hang, never a 5xx crash.
#   6. Graceful drain: POST /v1/shutdown and SIGTERM both complete
#      in-flight work and exit 0 with a drain summary.
#
# The byte-level malformed battery (truncated heads, header bombs, bogus
# content-lengths) lives in rust/tests/serve_wire.rs where the client can
# half-close sockets; this script exercises what curl can express.
#
# Usage: ci/serve_smoke.sh   (from the repo root; needs a release build —
# set SEGMUL to override the binary path, PORT/PORT2 to rebind).
set -euo pipefail
cd "$(dirname "$0")/../rust"

SEGMUL="${SEGMUL:-target/release/segmul}"
PORT="${PORT:-18787}"
PORT2="${PORT2:-18788}"
BASE="http://127.0.0.1:$PORT"
WORK="$(mktemp -d)"
SERVER_PID=""
cleanup() {
    [ -n "$SERVER_PID" ] && kill "$SERVER_PID" 2>/dev/null
    rm -rf "$WORK"
}
trap cleanup EXIT

status() { curl -s -o "$WORK/body" -w '%{http_code}' "$@"; }
body() { cat "$WORK/body"; }

wait_healthy() {
    local base=$1
    for _ in $(seq 1 100); do
        if [ "$(status "$base/healthz" || true)" = 200 ]; then return 0; fi
        sleep 0.1
    done
    echo "FAIL: server at $base never became healthy"
    exit 1
}

expect() {
    local want=$1 got=$2 what=$3
    if [ "$got" != "$want" ]; then
        echo "FAIL: $what: expected $want, got $got ($(body))"
        exit 1
    fi
    echo "ok: $what -> $got"
}

expect_body() {
    local needle=$1 what=$2
    if ! grep -q "$needle" "$WORK/body"; then
        echo "FAIL: $what: body lacks $needle: $(body)"
        exit 1
    fi
}

echo "== boot: $SEGMUL serve on $BASE =="
"$SEGMUL" serve --addr "127.0.0.1:$PORT" --workers 2 >"$WORK/server.log" 2>&1 &
SERVER_PID=$!
wait_healthy "$BASE"
grep -q '^backend: ' "$WORK/server.log" || {
    echo "FAIL: CLI did not print a machine-readable backend line"
    cat "$WORK/server.log"
    exit 1
}
echo "ok: $(grep '^backend: ' "$WORK/server.log")"

echo "== every endpoint answers =="
expect 200 "$(status "$BASE/healthz")" "GET /healthz"
expect_body '"status":"ok"' "/healthz status field"
expect 200 "$(status "$BASE/v1/designs")" "GET /v1/designs"
expect_body '"segmented"' "/v1/designs carries the paper family"
EVAL='{"design":{"family":"segmented","n":8,"t":3,"fix":true},"workload":{"kind":"mc","samples":200000,"seed":7}}'
expect 200 "$(status -d "$EVAL" "$BASE/v1/eval")" "POST /v1/eval"
expect_body '"source":"simulated"' "eval answer source"
expect_body '"backend"' "eval answer backend identity"
expect 200 "$(status -d '{"designs":"paper","bitwidths":[4]}' "$BASE/v1/sweep")" "POST /v1/sweep"
expect_body '"status":"complete"' "sweep stream trailer"
expect 200 "$(status "$BASE/metrics")" "GET /metrics"
expect_body '^serve_backend ' "/metrics backend identity"

echo "== coalesced burst: identical requests share one dispatch =="
BURST='{"design":{"family":"segmented","n":8,"t":2,"fix":false},"workload":{"kind":"mc","samples":2000000,"seed":99}}'
for i in $(seq 1 6); do
    curl -s -o "$WORK/burst$i" -w '%{http_code}\n' -d "$BURST" "$BASE/v1/eval" >>"$WORK/burst.codes" &
done
wait
sort -u "$WORK/burst.codes" | grep -qx 200 || { echo "FAIL: burst requests failed"; cat "$WORK/burst.codes"; exit 1; }
[ "$(sort -u "$WORK/burst.codes" | wc -l)" = 1 ] || { echo "FAIL: non-200 in burst"; cat "$WORK/burst.codes"; exit 1; }
status "$BASE/metrics" >/dev/null
requests=$(awk '/^serve_coalesce_requests /{print $2}' "$WORK/body")
dispatched=$(awk '/^serve_coalesce_dispatched /{print $2}' "$WORK/body")
echo "coalescing: $requests eval requests -> $dispatched pool dispatches"
[ "$dispatched" -lt "$requests" ] || {
    echo "FAIL: identical burst did not coalesce ($dispatched dispatches for $requests requests)"
    exit 1
}
# Only `cached`/`wall_ms` may differ between a dispatch and a cache hit;
# the metrics object must be byte-identical across the whole burst.
m1=$(grep -o '"metrics":{[^}]*}' "$WORK/burst1")
for i in $(seq 2 6); do
    mi=$(grep -o '"metrics":{[^}]*}' "$WORK/burst$i")
    [ -n "$m1" ] && [ "$m1" = "$mi" ] || { echo "FAIL: coalesced answers differ"; exit 1; }
done

echo "== malformed battery: typed JSON 4xx, server survives =="
expect 400 "$(status -d 'not json' "$BASE/v1/eval")" "garbage body"
expect_body '"kind":"serve"' "garbage body error kind"
expect 400 "$(status -d '{}' "$BASE/v1/eval")" "missing fields"
expect 400 "$(status -d '{"design":{"family":"warp","n":8},"workload":{"kind":"exhaustive"}}' "$BASE/v1/eval")" "unknown family"
expect 400 "$(status -d '{"design":{"family":"segmented","n":8,"t":9,"fix":false},"workload":{"kind":"exhaustive"}}' "$BASE/v1/eval")" "invalid segment count"
expect_body '"kind":"spec"' "spec validation error kind"
expect 404 "$(status "$BASE/nope")" "unknown route"
expect 405 "$(status -X DELETE "$BASE/metrics")" "wrong method"
head -c 1200000 /dev/zero | tr '\0' 'a' >"$WORK/huge"
expect 413 "$(status --data-binary "@$WORK/huge" "$BASE/v1/eval")" "oversized payload"
expect 200 "$(status "$BASE/healthz")" "health after the battery"

echo "== graceful drain via POST /v1/shutdown =="
expect 200 "$(status -d '{}' "$BASE/v1/shutdown")" "POST /v1/shutdown"
expect_body '"status":"draining"' "shutdown acknowledgement"
wait "$SERVER_PID"
SERVER_PID=""
grep -q '^drained: ' "$WORK/server.log" || {
    echo "FAIL: no drain summary in the server log"
    cat "$WORK/server.log"
    exit 1
}
echo "ok: $(grep '^drained: ' "$WORK/server.log")"

echo "== saturating burst against --max-inflight 2: typed 429s, no hangs =="
BASE2="http://127.0.0.1:$PORT2"
"$SEGMUL" serve --addr "127.0.0.1:$PORT2" --workers 2 --max-inflight 2 >"$WORK/server2.log" 2>&1 &
SERVER_PID=$!
wait_healthy "$BASE2"
: >"$WORK/sat.codes"
for i in $(seq 1 8); do
    curl -s -o /dev/null -w '%{http_code}\n' \
        -d '{"design":{"family":"segmented","n":16,"t":5,"fix":true},"workload":{"kind":"mc","samples":8000000,"seed":'"$i"'}}' \
        "$BASE2/v1/eval" >>"$WORK/sat.codes" &
done
wait
sort "$WORK/sat.codes" | uniq -c
grep -qx 200 "$WORK/sat.codes" || { echo "FAIL: saturation burst: nothing was admitted"; exit 1; }
grep -qx 429 "$WORK/sat.codes" || { echo "FAIL: saturation burst: no typed 429 rejection"; exit 1; }
if grep -vqx -e 200 -e 429 "$WORK/sat.codes"; then
    echo "FAIL: unexpected status in saturation burst"
    exit 1
fi
status "$BASE2/metrics" >/dev/null
rejected=$(awk '/^serve_rejected_429 /{print $2}' "$WORK/body")
echo "admission control: $rejected requests rejected with 429"
[ "$rejected" -ge 1 ] || { echo "FAIL: serve_rejected_429 not counted"; exit 1; }

echo "== graceful drain via SIGTERM =="
kill -TERM "$SERVER_PID"
wait "$SERVER_PID"
SERVER_PID=""
grep -q '^drained: ' "$WORK/server2.log" || {
    echo "FAIL: no drain summary after SIGTERM"
    cat "$WORK/server2.log"
    exit 1
}
echo "ok: $(grep '^drained: ' "$WORK/server2.log")"
echo "PASS: serve smoke"
