"""L2 — JAX evaluation graph over the L1 kernel.

`eval_stats` is the module that gets AOT-lowered to HLO text (one artifact
per bit-width n) and executed from the Rust coordinator's hot path: it runs
the batched approximate multiply (Pallas kernel), the exact product, the
signed error distance, and reduces everything to a fixed-size f64 statistics
vector ON DEVICE, so the host transfer is O(1) per batch instead of O(B).

Statistics vector layout (f64[6 + 2n]):

  [0] count          — number of evaluated pairs (== batch size)
  [1] err_count      — #{ p != p̂ }                        (for ER, Eq. 3)
  [2] sum_ed         — Σ ED = Σ (p - p̂), signed            (for MED, Eq. 6)
  [3] sum_abs_ed     — Σ |ED|                              (for MED of |ED|)
  [4] max_abs_ed     — max |ED|                            (for MAE, Eq. 5)
  [5] sum_red        — Σ |ED| / max(1, p)                  (for MRED, Eq. 8)
  [6 .. 6+2n)        — per-output-bit flip counts          (for BER, Eq. 2)

All sums are f64; |ED| < 2^{n+t} <= 2^63 so each term is exact, and the
f64 accumulation error over a 2^16 batch is < 2^-36 relative — negligible
against MC sampling noise (documented in EXPERIMENTS.md).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.seqmul import seqmul_phat, seqmul_word

STATS_FIXED = 6  # leading scalar slots before the 2n BER counters


def stats_len(n: int) -> int:
    """Length of the statistics vector for bit-width n."""
    return STATS_FIXED + 2 * n


def _stats_from_products(p, phat, n: int):
    """Reduce exact/approximate product vectors to the f64 stats vector."""
    # Signed ED = dec(p) - dec(p̂): u64 wrap-around subtract, then bitcast —
    # exact whenever |ED| < 2^63 (always true for n <= 32: |ED| < 2^{2n}).
    ed = jax.lax.bitcast_convert_type(p - phat, jnp.int64)
    abs_ed = jnp.abs(ed).astype(jnp.float64)
    ed_f = ed.astype(jnp.float64)
    p_f = p.astype(jnp.float64)

    count = jnp.float64(p.shape[0])
    err_count = jnp.sum(p != phat).astype(jnp.float64)
    sum_ed = jnp.sum(ed_f)
    sum_abs = jnp.sum(abs_ed)
    max_abs = jnp.max(abs_ed)
    sum_red = jnp.sum(abs_ed / jnp.maximum(1.0, p_f))

    # Per-bit flip counts via a fori_loop of streaming reductions: the
    # (B, 2n) broadcast matrix this replaces costs ~32 MB of memory
    # traffic per batch and dominated the module (§Perf: 20 ms -> 1.9 ms
    # at n = 32, B = 2^16).
    flips = p ^ phat
    one = jnp.uint64(1)

    def _count_bit(i, acc):
        cnt = jnp.sum((flips >> i.astype(jnp.uint64)) & one).astype(jnp.float64)
        return acc.at[i].set(cnt)

    bitflips = jax.lax.fori_loop(0, 2 * n, _count_bit, jnp.zeros(2 * n, jnp.float64))

    head = jnp.stack([count, err_count, sum_ed, sum_abs, max_abs, sum_red])
    return jnp.concatenate([head, bitflips])


def eval_stats(a, b, t, fix, *, n: int):
    """Full evaluation module: kernel + exact ref + on-device stats.

    Args:
      a, b: u64[B] operand batches, values < 2**n.
      t:    u64 scalar splitting point (runtime operand, 0 <= t < n).
      fix:  u64 scalar, nonzero enables fix-to-1.
      n:    static bit-width (one lowered artifact per n).

    Returns: (f64[6+2n],) — tuple for `return_tuple=True` interchange.
    """
    phat = seqmul_phat(a, b, t, fix, n=n)
    p = a * b  # exact product; fits u64 for n <= 32
    return (_stats_from_products(p, phat, n),)


def eval_products(a, b, t, fix, *, n: int):
    """Product-only module: returns the approximate products themselves.

    Used by the serving path when the caller wants values (e.g. the image
    filter demo) rather than aggregate statistics.
    """
    return (seqmul_phat(a, b, t, fix, n=n),)


def eval_stats_ref(a, b, t, fix, *, n: int):
    """Same graph but through the pure-jnp oracle (no Pallas) — used by
    pytest to check that kernel lowering and reference lowering agree."""
    phat = seqmul_word(a, b, t, fix, n=n)
    p = a * b
    return (_stats_from_products(p, phat, n),)
