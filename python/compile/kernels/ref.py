"""Correctness oracles for the segmented-carry sequential multiplier.

Two independent references:

* `seqmul_ref` — the pure-jnp word-level recurrence (no Pallas), used by
  pytest to check the kernel's lowering.
* `seqmul_bitlevel` — a literal, bit-by-bit transcription of the paper's
  `Ŝ_i^j` / `Ĉ_i^j` equations (§IV-A) over python ints. This is the ground
  truth the word-level model must match; it is deliberately written from the
  equations (not from the word-level algorithm) so the two can disagree if
  either mis-reads the paper.
"""

from __future__ import annotations

from .seqmul import seqmul_word


def seqmul_ref(a, b, t, fix, *, n):
    """Pure-jnp oracle (identical math to the kernel, no pallas_call)."""
    return seqmul_word(a, b, t, fix, n=n)


def seqmul_bitlevel(a: int, b: int, n: int, t: int, fix: bool) -> int:
    """Paper's Boolean recurrences, evaluated literally bit by bit.

    S[j][i] for i in [0, n] is the j-th accumulated sum (S[j][n] is the
    carry-out C_{n-1}^j per the paper); C[j][i] for i in [0, n) is the j-th
    carry chain. The approximate cases:
      * i = t (t >= 1): carry-in is the D-FF'd previous-cycle LSP carry-out
        C[j-1][t-1]  (the paper's `Ĉ_{i-1}^{j-1}` case),
      * all other i in (0, n): same-cycle ripple carry C[j][i-1].
    t = 0 yields the fully accurate multiplier.
    """
    if not (1 <= n):
        raise ValueError("n must be >= 1")
    if not (0 <= t <= n):
        raise ValueError("t must be in [0, n]")
    abit = [(a >> i) & 1 for i in range(n)]
    bbit = [(b >> j) & 1 for j in range(n)]

    S = [[0] * (n + 1) for _ in range(n)]
    C = [[0] * n for _ in range(n)]

    # j = 0: S^0 = a & -b_0, no carries (paper: C_i^0 = 0).
    for i in range(n):
        S[0][i] = abit[i] & bbit[0]
    S[0][n] = 0

    for j in range(1, n):
        pp0 = abit[0] & bbit[j]
        S[j][0] = S[j - 1][1] ^ pp0
        C[j][0] = S[j - 1][1] & pp0
        for i in range(1, n):
            pp = abit[i] & bbit[j]
            if i == t:
                cin = C[j - 1][t - 1]  # D flip-flop: previous cycle's carry
            else:
                cin = C[j][i - 1]  # same-cycle ripple
            S[j][i] = S[j - 1][i + 1] ^ cin ^ pp
            C[j][i] = ((S[j - 1][i + 1] ^ pp) & cin) | (S[j - 1][i + 1] & pp)
        S[j][n] = C[j][n - 1]

    # Product construction (paper's p̂_r cases).
    p = 0
    for r in range(0, n - 1):
        p |= S[r][0] << r
    for r in range(n - 1, 2 * n):
        p |= S[n - 1][r - n + 1] << r

    if fix and t >= 1 and n >= 2 and C[n - 1][t - 1] == 1:
        p |= (1 << (n + t)) - 1
    return p


def seqmul_py(a: int, b: int, n: int, t: int, fix: bool) -> int:
    """Word-level algorithm over python ints (third, independent check)."""
    mt = (1 << t) - 1
    s = a if (b & 1) else 0
    cff = 0
    low = 0
    for j in range(1, n):
        low |= (s & 1) << (j - 1)
        x = s >> 1
        pp = a if ((b >> j) & 1) else 0
        lsum = (x & mt) + (pp & mt)
        clsp = (lsum >> t) & 1
        msum = (x >> t) + (pp >> t) + cff
        s = (msum << t) | (lsum & mt)
        cff = clsp
    phat = (s << (n - 1)) | low
    if fix and cff == 1:
        phat |= (1 << (n + t)) - 1
    return phat
