"""L1 — Pallas kernel: batched segmented-carry sequential multiplier.

Implements the paper's approximate sequential multiplier (Echavarria et al.,
"On the Approximation of Accuracy-configurable Sequential Multipliers via
Segmented Carry Chains", 2021) as a word-level recurrence that is bit-exact
to the paper's `Ŝ_i^j` / `Ĉ_i^j` equations (§IV-A):

  per clock cycle j = 1 .. n-1 (cycle 0 loads `a & -b_0`):
    x    = s >> 1                         # previous sum, shifted right once
    pp   = b_j ? a : 0                    # partial product
    lsum = (x & M_t) + (pp & M_t)         # t-bit LSP adder (carry-in 0)
    msum = (x >> t) + (pp >> t) + cff     # (n-t)-bit MSP adder; carry-in is
                                          #   the D-FF'd LSP carry-out of the
                                          #   PREVIOUS cycle (the paper's
                                          #   i = t case using Ĉ_{t-1}^{j-1})
    s'   = (msum << t) | (lsum & M_t)     # (n+1)-bit accumulated sum
    cff' = (lsum >> t) & 1                # LSP carry-out into the D-FF
  and product bit p_{j-1} = s & 1 is shifted out into register B each cycle.

After the last cycle `p̂[2n-1 .. n-1] = s` and, when the final LSP carry-out
is 1 and fix-to-1 is enabled, the n+t LSBs of `p̂` are forced to 1
(the paper's `fix-to-1` instrumentation, §IV-A).

`t = 0` degenerates to the fully accurate sequential multiplier (the LSP
adder is empty, so the D-FF never captures a carry) — this is tested.

The kernel is a VPU-style elementwise kernel: the recurrence is sequential
in j but embarrassingly parallel across input pairs, so the batch dimension
is tiled into VMEM-sized blocks (`TILE` lanes) via BlockSpec and the n-cycle
`fori_loop` runs per lane. `interpret=True` — the CPU PJRT client cannot run
Mosaic custom-calls (see DESIGN.md §Hardware-Adaptation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Lanes per grid step. 8*128-friendly; at n=32 the live state is
# ~6 u64 vectors * TILE = 768 KiB per tile, still well under VMEM.
# (16384 measured ~9% faster than 4096 on the CPU backend; see
# EXPERIMENTS.md §Perf.)
TILE = 16384

_U64 = jnp.uint64


def _u64(x) -> jnp.ndarray:
    return jnp.asarray(x, _U64)


def _mask_lo(nbits):
    """(1 << nbits) - 1 as u64, correct for nbits >= 64 (all-ones)."""
    one = _u64(1)
    wide = nbits >= _u64(64)
    safe = jnp.where(wide, _u64(0), nbits)
    return jnp.where(wide, ~_u64(0), (one << safe) - one)


def seqmul_word(a, b, t, fix, *, n):
    """Pure-jnp word-level recurrence (shared by the kernel and `ref.py`).

    Args:
      a, b: u64 arrays (any broadcastable shape), values < 2**n.
      t:    u64 scalar splitting point, 0 <= t <= n. t = 0 is accurate.
      fix:  u64 scalar; nonzero enables fix-to-1.
      n:    static python int bit-width, 1 <= n <= 32.

    Returns: u64 array of approximate products `p̂`.
    """
    a = _u64(a)
    b = _u64(b)
    t = _u64(t)
    fix = _u64(fix)
    one = _u64(1)
    zero = _u64(0)
    mt = _mask_lo(t)

    s0 = jnp.where((b & one) != zero, a, zero)
    cff0 = jnp.zeros_like(s0)
    low0 = jnp.zeros_like(s0)

    def body(j, state):
        s, cff, low = state
        ju = _u64(j)
        low = low | ((s & one) << (ju - one))  # p_{j-1} = S_0^{j-1}
        x = s >> one
        pp = jnp.where(((b >> ju) & one) != zero, a, zero)
        lsum = (x & mt) + (pp & mt)
        clsp = (lsum >> t) & one
        msum = (x >> t) + (pp >> t) + cff
        s = (msum << t) | (lsum & mt)
        return s, clsp, low

    s, cff, low = jax.lax.fori_loop(1, n, body, (s0, cff0, low0))
    phat = (s << _u64(n - 1)) | low
    fixmask = _mask_lo(_u64(n) + t)
    do_fix = jnp.logical_and(fix != zero, cff == one)
    return jnp.where(do_fix, phat | fixmask, phat)


def _seqmul_kernel(n, a_ref, b_ref, t_ref, fix_ref, o_ref):
    o_ref[...] = seqmul_word(a_ref[...], b_ref[...], t_ref[0], fix_ref[0], n=n)


def seqmul_phat(a, b, t, fix, *, n, tile=None):
    """Batched approximate product via the Pallas kernel.

    `a`, `b` are u64[B] with B a multiple of `tile`; `t`/`fix` are scalars
    (python ints or traced u64) — they are runtime operands, so one lowered
    artifact serves every accuracy configuration of a given bit-width n.
    """
    batch = a.shape[0]
    if tile is None:
        tile = min(TILE, batch)
    if batch % tile != 0:
        raise ValueError(f"batch {batch} not a multiple of tile {tile}")
    t_arr = jnp.reshape(_u64(t), (1,))
    fix_arr = jnp.reshape(_u64(fix), (1,))
    kernel = functools.partial(_seqmul_kernel, n)
    return pl.pallas_call(
        kernel,
        grid=(batch // tile,),
        in_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((batch,), _U64),
        interpret=True,
    )(_u64(a), _u64(b), t_arr, fix_arr)
