"""AOT lowering: JAX -> HLO *text* artifacts for the Rust PJRT runtime.

Run once by `make artifacts`; python never executes on the request path.

Interchange format is HLO text, NOT `.serialize()` / serialized
HloModuleProto: jax >= 0.5 emits protos with 64-bit instruction ids which
the xla crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`). The
text parser reassigns ids and round-trips cleanly — see
/opt/xla-example/README.md.

Emits, per bit-width n in {4, 8, 16, 32}:
  artifacts/seqmul_stats_n{n}.hlo.txt — eval_stats  (the service hot path)
  artifacts/seqmul_prod_n{n}.hlo.txt  — eval_products (value-returning path)
plus artifacts/manifest.json describing shapes/dtypes for the Rust loader.
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from .model import eval_products, eval_stats, stats_len  # noqa: E402

BITWIDTHS = (4, 8, 16, 32)
BATCH = 65536


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_module(fn, n: int, batch: int) -> str:
    vec = jax.ShapeDtypeStruct((batch,), jnp.uint64)
    scalar = jax.ShapeDtypeStruct((), jnp.uint64)
    lowered = jax.jit(functools.partial(fn, n=n)).lower(vec, vec, scalar, scalar)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--batch", type=int, default=BATCH)
    ap.add_argument(
        "--bitwidths", type=int, nargs="*", default=list(BITWIDTHS)
    )
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)

    manifest = {"batch": args.batch, "modules": []}
    for n in args.bitwidths:
        for kind, fn in (("stats", eval_stats), ("prod", eval_products)):
            name = f"seqmul_{kind}_n{n}"
            path = os.path.join(args.outdir, f"{name}.hlo.txt")
            text = lower_module(fn, n, args.batch)
            with open(path, "w") as f:
                f.write(text)
            out = (
                {"dtype": "f64", "shape": [stats_len(n)]}
                if kind == "stats"
                else {"dtype": "u64", "shape": [args.batch]}
            )
            manifest["modules"].append(
                {
                    "name": name,
                    "kind": kind,
                    "n": n,
                    "file": os.path.basename(path),
                    "inputs": [
                        {"name": "a", "dtype": "u64", "shape": [args.batch]},
                        {"name": "b", "dtype": "u64", "shape": [args.batch]},
                        {"name": "t", "dtype": "u64", "shape": []},
                        {"name": "fix", "dtype": "u64", "shape": []},
                    ],
                    "output": out,
                }
            )
            print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.outdir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
