"""Pallas kernel vs pure-jnp reference vs the two python-int oracles.

This is the CORE correctness signal for L1: the kernel must agree with
  (a) `seqmul_ref`      — the same word-level math without pallas_call,
  (b) `seqmul_bitlevel` — a literal transcription of the paper's Ŝ/Ĉ
      Boolean recurrences, and
  (c) `seqmul_py`       — the word-level algorithm over python ints.
`hypothesis` sweeps bit-widths, splitting points, fix-to-1, and batch
shapes.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels.ref import seqmul_bitlevel, seqmul_py, seqmul_ref
from compile.kernels.seqmul import seqmul_phat


def _rand(n, size, seed):
    rng = np.random.default_rng(seed)
    hi = 1 << n
    return (
        rng.integers(0, hi, size=size, dtype=np.uint64),
        rng.integers(0, hi, size=size, dtype=np.uint64),
    )


@pytest.mark.parametrize("n", [2, 4, 8, 16, 32])
@pytest.mark.parametrize("fix", [0, 1])
def test_kernel_matches_ref_random(n, fix):
    a, b = _rand(n, 512, seed=n * 7 + fix)
    for t in range(0, n, max(1, n // 4)):
        got = np.asarray(seqmul_phat(jnp.asarray(a), jnp.asarray(b), t, fix, n=n, tile=256))
        want = np.asarray(seqmul_ref(jnp.asarray(a), jnp.asarray(b), t, fix, n=n))
        np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("n,t", [(4, 2), (6, 3), (8, 3)])
@pytest.mark.parametrize("fix", [0, 1])
def test_kernel_matches_bitlevel_oracle(n, t, fix):
    a, b = _rand(n, 256, seed=n + t + fix)
    got = np.asarray(seqmul_phat(jnp.asarray(a), jnp.asarray(b), t, fix, n=n, tile=256))
    want = np.array(
        [seqmul_bitlevel(int(x), int(y), n, t, bool(fix)) for x, y in zip(a, b)],
        dtype=np.uint64,
    )
    np.testing.assert_array_equal(got, want)


def test_accurate_when_t_zero():
    for n in (4, 8, 16, 32):
        a, b = _rand(n, 512, seed=n)
        got = np.asarray(seqmul_phat(jnp.asarray(a), jnp.asarray(b), 0, 0, n=n, tile=256))
        np.testing.assert_array_equal(got, a * b)  # u64 wrap-free for n<=32


def test_paper_table2_example():
    """Table IIb: a=1011, b=0110, n=4, t=2. Exact product is 66; the delayed
    LSP carry enters one position high, overshooting by 2^{t+j} = 16."""
    got = seqmul_py(0b1011, 0b0110, 4, 2, False)
    assert got == 82
    assert 0b1011 * 0b0110 == 66


def test_grid_tiling_invariance():
    """Same batch through different tile sizes must give identical bits."""
    n = 8
    a, b = _rand(n, 1024, seed=3)
    ref = np.asarray(seqmul_phat(jnp.asarray(a), jnp.asarray(b), 3, 1, n=n, tile=1024))
    for tile in (128, 256, 512):
        got = np.asarray(seqmul_phat(jnp.asarray(a), jnp.asarray(b), 3, 1, n=n, tile=tile))
        np.testing.assert_array_equal(got, ref)


def test_batch_not_multiple_of_tile_raises():
    a = jnp.zeros((100,), jnp.uint64)
    with pytest.raises(ValueError):
        seqmul_phat(a, a, 1, 0, n=4, tile=64)


@settings(max_examples=60, deadline=None)
@given(
    n=st.integers(min_value=2, max_value=16),
    data=st.data(),
)
def test_hypothesis_wordlevel_equals_bitlevel(n, data):
    """Property: the word-level algorithm is bit-exact to the paper's
    Boolean recurrences for every (n, t, fix, a, b)."""
    t = data.draw(st.integers(min_value=0, max_value=n - 1))
    fix = data.draw(st.booleans())
    a = data.draw(st.integers(min_value=0, max_value=(1 << n) - 1))
    b = data.draw(st.integers(min_value=0, max_value=(1 << n) - 1))
    assert seqmul_py(a, b, n, t, fix) == seqmul_bitlevel(a, b, n, t, fix)


@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from([2, 3, 4, 6, 8, 12, 16, 24, 32]),
    data=st.data(),
)
def test_hypothesis_kernel_equals_pyint(n, data):
    """Property: the Pallas kernel agrees with the python-int word model on
    random batches across the full (n, t, fix) configuration space."""
    t = data.draw(st.integers(min_value=0, max_value=n - 1))
    fix = data.draw(st.booleans())
    seed = data.draw(st.integers(min_value=0, max_value=2**31))
    a, b = _rand(n, 64, seed=seed)
    got = np.asarray(seqmul_phat(jnp.asarray(a), jnp.asarray(b), t, int(fix), n=n, tile=64))
    want = np.array(
        [seqmul_py(int(x), int(y), n, t, fix) for x, y in zip(a, b)],
        dtype=np.uint64,
    )
    np.testing.assert_array_equal(got, want)


def test_exhaustive_n4_all_t_fix():
    """Exhaustive ground truth at n=4: kernel == bit-level for all 256
    input pairs, every splitting point, fix on/off."""
    n = 4
    aa, bb = np.meshgrid(np.arange(16, dtype=np.uint64), np.arange(16, dtype=np.uint64))
    a = aa.ravel()
    b = bb.ravel()
    for t in range(n):
        for fix in (0, 1):
            got = np.asarray(seqmul_phat(jnp.asarray(a), jnp.asarray(b), t, fix, n=n, tile=256))
            want = np.array(
                [seqmul_bitlevel(int(x), int(y), n, t, bool(fix)) for x, y in zip(a, b)],
                dtype=np.uint64,
            )
            np.testing.assert_array_equal(got, want)
