"""L2 model tests: the on-device statistics vector vs numpy brute force."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.ref import seqmul_py
from compile.model import STATS_FIXED, eval_stats, eval_stats_ref, stats_len


def _brute_stats(a, b, n, t, fix):
    """Independent numpy/python-int computation of the stats vector."""
    phat = np.array([seqmul_py(int(x), int(y), n, t, bool(fix)) for x, y in zip(a, b)], dtype=object)
    p = np.array([int(x) * int(y) for x, y in zip(a, b)], dtype=object)
    ed = np.array([int(pi) - int(qi) for pi, qi in zip(p, phat)], dtype=object)
    stats = np.zeros(stats_len(n))
    stats[0] = len(a)
    stats[1] = sum(1 for e in ed if e != 0)
    stats[2] = float(sum(ed))
    stats[3] = float(sum(abs(e) for e in ed))
    stats[4] = float(max(abs(e) for e in ed))
    stats[5] = float(sum(abs(e) / max(1, int(pi)) for e, pi in zip(ed, p)))
    for i in range(2 * n):
        stats[STATS_FIXED + i] = sum(((int(pi) ^ int(qi)) >> i) & 1 for pi, qi in zip(p, phat))
    return stats


@pytest.mark.parametrize("n,t,fix", [(4, 2, 0), (4, 2, 1), (8, 3, 0), (8, 4, 1), (16, 8, 1)])
def test_stats_vs_brute(n, t, fix):
    rng = np.random.default_rng(n * 100 + t)
    a = rng.integers(0, 1 << n, size=256, dtype=np.uint64)
    b = rng.integers(0, 1 << n, size=256, dtype=np.uint64)
    (got,) = eval_stats(jnp.asarray(a), jnp.asarray(b), jnp.uint64(t), jnp.uint64(fix), n=n)
    want = _brute_stats(a, b, n, t, fix)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-12)


@pytest.mark.parametrize("n", [4, 8, 16, 32])
def test_stats_kernel_equals_ref_graph(n):
    rng = np.random.default_rng(n)
    a = jnp.asarray(rng.integers(0, 1 << n, size=512, dtype=np.uint64))
    b = jnp.asarray(rng.integers(0, 1 << n, size=512, dtype=np.uint64))
    t, fix = jnp.uint64(max(1, n // 2)), jnp.uint64(1)
    (got,) = eval_stats(a, b, t, fix, n=n)
    (want,) = eval_stats_ref(a, b, t, fix, n=n)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_stats_zero_error_when_accurate():
    n = 16
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.integers(0, 1 << n, size=512, dtype=np.uint64))
    b = jnp.asarray(rng.integers(0, 1 << n, size=512, dtype=np.uint64))
    (s,) = eval_stats(a, b, jnp.uint64(0), jnp.uint64(0), n=n)
    s = np.asarray(s)
    assert s[0] == 512
    np.testing.assert_array_equal(s[1:], np.zeros(stats_len(n) - 1))


def test_stats_vector_layout():
    assert stats_len(4) == 6 + 8
    assert stats_len(32) == 6 + 64
